//! Integration: Swan engine + PJRT numerics on one simulated phone —
//! the full local story (explore → train → interfere → migrate) with a
//! real model learning underneath.
//!
//! QUARANTINE: every test touching the PJRT runtime is `#[ignore]`d —
//! the artifacts (`artifacts/*.hlo.txt`) are not checked in and the
//! offline build links the `src/xla.rs` stub instead of the real
//! bindings. Run `make artifacts` and build with the real `xla` crate,
//! then `cargo test -- --ignored`, to exercise them.

use swan::baseline::GreedyBaseline;
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::sim::interference::SessionGenerator;
use swan::sim::SimPhone;
use swan::soc::device::{device, DeviceId};
use swan::swan::{SwanConfig, SwanEngine};
use swan::train::data::SyntheticDataset;
use swan::train::trainer::{LocalTrainer, Policy};
use swan::workload::{load_or_builtin, WorkloadName};

fn registry_or_skip() -> Option<Registry> {
    match Registry::discover() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn swan_trains_faster_and_cheaper_than_baseline_on_s10e() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "shufflenet_s").unwrap();
    let d = device(DeviceId::S10e);
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let ds = SyntheticDataset::vision(5);

    let steps = 10;

    // Swan arm: explore on a scratch phone, then run on a fresh phone so
    // exploration drain doesn't pollute the comparison
    let mut scratch = SimPhone::new(d.clone(), 1);
    let engine = SwanEngine::explore_and_build(
        &mut scratch,
        workload.clone(),
        SwanConfig::default(),
    );
    let mut policy_a = Policy::Swan(engine);
    let mut state_a = exec.init_state(3).unwrap();
    let mut trainer_a =
        LocalTrainer::new(&exec, ds.clone(), ds.partition(0));
    let mut phone_a = SimPhone::new(d.clone(), 2);
    let rep_a = trainer_a
        .run_local_steps(&mut policy_a, &mut phone_a, &mut state_a, steps)
        .unwrap();

    // Baseline arm
    let mut phone_b = SimPhone::new(d.clone(), 2);
    let mut policy_b =
        Policy::Greedy(GreedyBaseline::new(&d, workload.clone()));
    let mut state_b = exec.init_state(3).unwrap();
    let mut trainer_b =
        LocalTrainer::new(&exec, ds.clone(), ds.partition(0));
    let rep_b = trainer_b
        .run_local_steps(&mut policy_b, &mut phone_b, &mut state_b, steps)
        .unwrap();

    // identical numerics (same seed, same data): losses must match
    assert_eq!(rep_a.losses, rep_b.losses, "numerics must be policy-free");
    // but Swan's systems cost is far lower on the S10e (paper: 39×/39×)
    assert!(
        rep_b.sim_seconds > 5.0 * rep_a.sim_seconds,
        "swan {}s vs baseline {}s",
        rep_a.sim_seconds,
        rep_b.sim_seconds
    );
    assert!(
        rep_b.energy_j > 5.0 * rep_a.energy_j,
        "swan {}J vs baseline {}J",
        rep_a.energy_j,
        rep_b.energy_j
    );
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn engine_migrates_while_really_training() {
    // ResNet-34 on Pixel 3: Swan's best choice is all four big cores, so
    // a 2-thread foreground app cannot be escaped by within-cluster
    // remapping — the controller MUST downgrade. (For single-core
    // choices like MobileNet's, the remap absorbs interference without
    // migration — covered by swan_single_core_choice_absorbs_interference.)
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "resnet_s").unwrap();
    let d = device(DeviceId::Pixel3);
    let workload = load_or_builtin(WorkloadName::Resnet34, "artifacts");

    let mut phone = SimPhone::new(d.clone(), 7);
    let engine = SwanEngine::explore_and_build(
        &mut phone,
        workload,
        SwanConfig::default(),
    );
    let start_choice = engine.best_profile().choice.label();
    let mut policy = Policy::Swan(engine);
    let ds = SyntheticDataset::speech(9);
    let mut trainer = LocalTrainer::new(&exec, ds.clone(), ds.partition(1));
    let mut state = exec.init_state(11).unwrap();

    // heavy endless foreground session arrives
    phone.sessions = SessionGenerator::new(13, 1e-6, 1e15, 1.0);
    phone.idle(1.0);
    trainer
        .run_local_steps(&mut policy, &mut phone, &mut state, 25)
        .unwrap();
    let Policy::Swan(engine) = &policy else { unreachable!() };
    let (downs, _ups) = engine.migrations();
    assert!(downs > 0, "no migration under persistent interference");
    assert_ne!(
        engine.current_choice().choice.label(),
        start_choice,
        "engine should have moved off {start_choice}"
    );
    // training remained real through the turbulence
    assert_eq!(state.steps, 25);
}


#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn swan_single_core_choice_absorbs_interference() {
    // MobileNet on Pixel 3: Swan's best choice is a single big core;
    // under a 2-thread foreground session the affinity remap moves the
    // thread to an idle big core and NO migration is needed — latency
    // stays at the profiled expectation.
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "mobilenet_s").unwrap();
    let d = device(DeviceId::Pixel3);
    let workload = load_or_builtin(WorkloadName::MobilenetV2, "artifacts");
    let mut phone = SimPhone::new(d.clone(), 7);
    let engine = SwanEngine::explore_and_build(
        &mut phone,
        workload,
        SwanConfig::default(),
    );
    assert_eq!(engine.best_profile().choice.n_threads(), 1);
    let expected = engine.best_profile().latency_s;
    let mut policy = Policy::Swan(engine);
    let ds = SyntheticDataset::vision(9);
    let mut trainer = LocalTrainer::new(&exec, ds.clone(), ds.partition(1));
    let mut state = exec.init_state(11).unwrap();
    phone.sessions = SessionGenerator::new(13, 1e-6, 1e15, 1.0);
    phone.idle(1.0);
    let rep = trainer
        .run_local_steps(&mut policy, &mut phone, &mut state, 10)
        .unwrap();
    let Policy::Swan(engine) = &policy else { unreachable!() };
    let (downs, _) = engine.migrations();
    assert_eq!(downs, 0, "remap should absorb the interference");
    let mean = rep.sim_seconds / rep.steps as f64;
    assert!(
        (mean - expected).abs() / expected < 0.2,
        "latency {mean} vs expected {expected}"
    );
}
