//! Integration: the fleet kernel's determinism contract — the same
//! `ScenarioSpec` must produce bit-identical aggregate metrics at every
//! shard count — plus `FlSim`'s systems-only path riding the same
//! kernel. No artifacts required.

use swan::fl::{FlArm, FlConfig, FlOutcome, FlSim};
use swan::fleet::{run_scenario, ScenarioSpec};
use swan::train::data::SyntheticDataset;
use swan::workload::{load_or_builtin, WorkloadName};

fn spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "determinism".to_string(),
        devices: 1_200,
        rounds: 15,
        clients_per_round: 60,
        trace_users: 3,
        ..ScenarioSpec::default()
    }
}

#[test]
fn scenario_reshard_bit_identical() {
    let spec = spec();
    let one = run_scenario(&spec, 1, FlArm::Swan).unwrap();
    let four = run_scenario(&spec, 4, FlArm::Swan).unwrap();
    let nine = run_scenario(&spec, 9, FlArm::Swan).unwrap();
    assert_eq!(one.digest(), four.digest(), "1 vs 4 shards");
    assert_eq!(one.digest(), nine.digest(), "1 vs 9 shards");
    assert_eq!(one.online_per_round, four.online_per_round);
    assert_eq!(one.total_time_s.to_bits(), four.total_time_s.to_bits());
    assert_eq!(
        one.total_energy_j.to_bits(),
        four.total_energy_j.to_bits()
    );
    assert_eq!(one.total_steps, four.total_steps);
    assert_eq!(one.participations, four.participations);
    // and the run is not degenerate
    assert!(one.participations > 0, "nobody ever participated");
    assert!(one.online_first() > 0, "fleet never online");
}

#[test]
fn scenario_repeat_run_identical() {
    let spec = spec();
    let a = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    let b = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    assert_eq!(a.digest(), b.digest(), "same spec must replay exactly");
}

fn fl_outcome_bits(o: &FlOutcome) -> (u64, u64, usize, Vec<(usize, usize)>) {
    (
        o.total_time_s.to_bits(),
        o.total_energy_j.to_bits(),
        o.rounds_run,
        o.online_per_round.clone(),
    )
}

#[test]
fn fl_sim_systems_only_reshard_identical() {
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let cfg = FlConfig {
        seed: 11,
        raw_traces: 16,
        quality_traces: 4,
        clients_per_round: 20,
        daily_credit_j: 800.0,
        ..FlConfig::default()
    };
    let run = |shards: usize| {
        let ds = SyntheticDataset::vision(cfg.seed);
        let mut sim =
            FlSim::new(cfg.clone(), FlArm::Swan, ds, &workload).unwrap();
        sim.run_systems_only_sharded(300, shards)
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(fl_outcome_bits(&one), fl_outcome_bits(&four));
    assert!(one.rounds_run > 0);
    assert!(one.total_energy_j > 0.0);
}

#[test]
fn fl_sim_clients_survive_the_kernel_round_trip() {
    // run_systems_only moves clients into the kernel and back; the
    // fleet must come back whole, in order, with loans advanced
    let workload = load_or_builtin(WorkloadName::MobilenetV2, "artifacts");
    let cfg = FlConfig {
        seed: 5,
        raw_traces: 8,
        quality_traces: 2,
        ..FlConfig::default()
    };
    let ds = SyntheticDataset::vision(cfg.seed);
    let mut sim = FlSim::new(cfg, FlArm::Swan, ds, &workload).unwrap();
    let n = sim.clients.len();
    let ids: Vec<usize> = sim.clients.iter().map(|c| c.id).collect();
    let out = sim.run_systems_only(200);
    assert_eq!(sim.clients.len(), n, "clients lost in the kernel");
    let ids_after: Vec<usize> = sim.clients.iter().map(|c| c.id).collect();
    assert_eq!(ids, ids_after, "client order must be restored");
    let parts: usize = sim.clients.iter().map(|c| c.participations).sum();
    assert!(parts > 0, "nobody participated over 200 rounds");
    assert!(out.total_time_s > 0.0);
}

#[test]
fn fleet_swan_keeps_more_of_the_fleet_online() {
    // the Figs 5b/6b mechanism at fleet scale: under a tight charger
    // envelope the greedy baseline exhausts energy loans faster than
    // Swan, so its online population decays further
    let spec = ScenarioSpec {
        name: "budget".to_string(),
        devices: 800,
        rounds: 800,
        clients_per_round: 400,
        local_steps: 20,
        trace_users: 2,
        daily_credit_j: 300.0,
        interference_p: 0.0,
        thermal_throttle_p: 0.0,
        ..ScenarioSpec::default()
    };
    let swan_out = run_scenario(&spec, 4, FlArm::Swan).unwrap();
    let base_out = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    let tail = |o: &swan::fleet::FleetOutcome| {
        let n = o.online_per_round.len();
        o.online_per_round[n - 100..]
            .iter()
            .map(|(_, c)| *c)
            .sum::<usize>() as f64
            / 100.0
    };
    assert!(
        tail(&swan_out) > tail(&base_out),
        "swan tail {} must beat baseline tail {}",
        tail(&swan_out),
        tail(&base_out)
    );
    assert!(base_out.total_energy_j > swan_out.total_energy_j);
}
