//! Integration: the fleet kernels' determinism contract — the same
//! `ScenarioSpec` must produce bit-identical aggregate metrics at every
//! shard count, on BOTH kernels (the PR 1 `ShardedEventLoop` reference
//! and the PR 2 SoA kernel), with the reference as the golden oracle —
//! plus `FlSim`'s systems-only path riding the generic kernel. No
//! artifacts required.

use swan::fl::{FlArm, FlConfig, FlOutcome, FlSim};
use swan::fleet::{
    run_scenario, run_scenario_reference, ScenarioSpec, SoaFleet,
    KERNEL_EVENT_LOOP, KERNEL_SOA,
};
use swan::train::data::SyntheticDataset;
use swan::workload::{load_or_builtin, WorkloadName};

fn spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "determinism".to_string(),
        devices: 1_200,
        rounds: 15,
        clients_per_round: 60,
        trace_users: 3,
        ..ScenarioSpec::default()
    }
}

#[test]
fn scenario_reshard_bit_identical() {
    let spec = spec();
    let one = run_scenario(&spec, 1, FlArm::Swan).unwrap();
    let four = run_scenario(&spec, 4, FlArm::Swan).unwrap();
    let nine = run_scenario(&spec, 9, FlArm::Swan).unwrap();
    assert_eq!(one.digest(), four.digest(), "1 vs 4 shards");
    assert_eq!(one.digest(), nine.digest(), "1 vs 9 shards");
    assert_eq!(one.online_per_round, four.online_per_round);
    assert_eq!(one.total_time_s.to_bits(), four.total_time_s.to_bits());
    assert_eq!(
        one.total_energy_j.to_bits(),
        four.total_energy_j.to_bits()
    );
    assert_eq!(one.total_steps, four.total_steps);
    assert_eq!(one.participations, four.participations);
    // and the run is not degenerate
    assert!(one.participations > 0, "nobody ever participated");
    assert!(one.online_first() > 0, "fleet never online");
}

#[test]
fn scenario_repeat_run_identical() {
    let spec = spec();
    let a = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    let b = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    assert_eq!(a.digest(), b.digest(), "same spec must replay exactly");
}

#[test]
fn golden_aggregates_at_1_2_3_7_16_shards_and_kernel_parity() {
    // the golden aggregate is the 1-shard PR 1 reference-kernel run;
    // every shard count, on either kernel, must reproduce it bit-exactly
    let spec = spec();
    let golden = run_scenario_reference(&spec, 1, FlArm::Swan).unwrap();
    assert_eq!(golden.kernel, KERNEL_EVENT_LOOP);
    assert!(golden.participations > 0, "degenerate golden run");
    for shards in [1usize, 2, 3, 7, 16] {
        let soa = run_scenario(&spec, shards, FlArm::Swan).unwrap();
        assert_eq!(soa.kernel, KERNEL_SOA);
        assert_eq!(
            soa.digest(),
            golden.digest(),
            "soa kernel diverged from golden at {shards} shards"
        );
        assert_eq!(soa.online_per_round, golden.online_per_round);
        assert_eq!(
            soa.total_time_s.to_bits(),
            golden.total_time_s.to_bits()
        );
        assert_eq!(
            soa.total_energy_j.to_bits(),
            golden.total_energy_j.to_bits()
        );
        assert_eq!(soa.total_steps, golden.total_steps);
        assert_eq!(soa.participations, golden.participations);
    }
    // …and the reference kernel agrees with itself when resharded
    for shards in [3usize, 16] {
        let reference =
            run_scenario_reference(&spec, shards, FlArm::Swan).unwrap();
        assert_eq!(
            reference.digest(),
            golden.digest(),
            "reference kernel diverged from golden at {shards} shards"
        );
    }
}

#[test]
fn soa_reassembly_matches_pr1_reassembly_order() {
    // the PR 1 reassembly (ShardedEventLoop::into_nodes) and the SoA
    // teardown (SoaFleet::into_devices) must restore the same global
    // order from the same population
    let spec = ScenarioSpec {
        name: "parity".to_string(),
        devices: 41,
        trace_users: 2,
        ..ScenarioSpec::default()
    };
    let via_engine = swan::fleet::ShardedEventLoop::new(
        spec.build_fleet().unwrap(),
        5,
    )
    .into_nodes()
    .unwrap();
    let via_soa = SoaFleet::new(spec.build_fleet().unwrap(), 5)
        .into_devices()
        .unwrap();
    assert_eq!(via_engine.len(), via_soa.len());
    for (a, b) in via_engine.iter().zip(&via_soa) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.model, b.model);
        assert_eq!(a.shift_s.to_bits(), b.shift_s.to_bits());
    }
    for (i, d) in via_soa.iter().enumerate() {
        assert_eq!(d.id, i, "global order must be restored");
    }
}

fn fl_outcome_bits(o: &FlOutcome) -> (u64, u64, usize, Vec<(usize, usize)>) {
    (
        o.total_time_s.to_bits(),
        o.total_energy_j.to_bits(),
        o.rounds_run,
        o.online_per_round.clone(),
    )
}

#[test]
fn fl_sim_systems_only_reshard_identical() {
    let workload = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let cfg = FlConfig {
        seed: 11,
        raw_traces: 16,
        quality_traces: 4,
        clients_per_round: 20,
        daily_credit_j: 800.0,
        ..FlConfig::default()
    };
    let run = |shards: usize| {
        let ds = SyntheticDataset::vision(cfg.seed);
        let mut sim =
            FlSim::new(cfg.clone(), FlArm::Swan, ds, &workload).unwrap();
        sim.run_systems_only_sharded(300, shards).unwrap()
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(fl_outcome_bits(&one), fl_outcome_bits(&four));
    assert!(one.rounds_run > 0);
    assert!(one.total_energy_j > 0.0);
}

#[test]
fn fl_sim_clients_survive_the_kernel_round_trip() {
    // run_systems_only moves clients into the kernel and back; the
    // fleet must come back whole, in order, with loans advanced
    let workload = load_or_builtin(WorkloadName::MobilenetV2, "artifacts");
    let cfg = FlConfig {
        seed: 5,
        raw_traces: 8,
        quality_traces: 2,
        ..FlConfig::default()
    };
    let ds = SyntheticDataset::vision(cfg.seed);
    let mut sim = FlSim::new(cfg, FlArm::Swan, ds, &workload).unwrap();
    let n = sim.clients.len();
    let ids: Vec<usize> = sim.clients.iter().map(|c| c.id).collect();
    let out = sim.run_systems_only(200).unwrap();
    assert_eq!(sim.clients.len(), n, "clients lost in the kernel");
    let ids_after: Vec<usize> = sim.clients.iter().map(|c| c.id).collect();
    assert_eq!(ids, ids_after, "client order must be restored");
    let parts: usize = sim.clients.iter().map(|c| c.participations).sum();
    assert!(parts > 0, "nobody participated over 200 rounds");
    assert!(out.total_time_s > 0.0);
}

#[test]
fn fleet_swan_keeps_more_of_the_fleet_online() {
    // the Figs 5b/6b mechanism at fleet scale: under a tight charger
    // envelope the greedy baseline exhausts energy loans faster than
    // Swan, so its online population decays further
    let spec = ScenarioSpec {
        name: "budget".to_string(),
        devices: 800,
        rounds: 800,
        clients_per_round: 400,
        local_steps: 20,
        trace_users: 2,
        daily_credit_j: 300.0,
        interference_p: 0.0,
        thermal_throttle_p: 0.0,
        ..ScenarioSpec::default()
    };
    let swan_out = run_scenario(&spec, 4, FlArm::Swan).unwrap();
    let base_out = run_scenario(&spec, 4, FlArm::Baseline).unwrap();
    let tail = |o: &swan::fleet::FleetOutcome| {
        let n = o.online_per_round.len();
        o.online_per_round[n - 100..]
            .iter()
            .map(|(_, c)| *c)
            .sum::<usize>() as f64
            / 100.0
    };
    assert!(
        tail(&swan_out) > tail(&base_out),
        "swan tail {} must beat baseline tail {}",
        tail(&swan_out),
        tail(&base_out)
    );
    assert!(base_out.total_energy_j > swan_out.total_energy_j);
}
