//! Integration: the authoritative AOT → PJRT round-trip.
//!
//! Loads the real artifacts produced by `make artifacts`, compiles them
//! on the PJRT CPU client, and checks (a) execution works, (b) loss
//! decreases under training — i.e. the gradients flowing through the
//! Pallas custom-vjp kernels are real, (c) eval counts are sane, and
//! (d) the host round-trip of parameters is lossless.
//!
//! Skips (with a message) if artifacts aren't built.
//!
//! QUARANTINE: every test touching the PJRT runtime is `#[ignore]`d —
//! the artifacts (`artifacts/*.hlo.txt`) are not checked in and the
//! offline build links the `src/xla.rs` stub instead of the real
//! bindings. Run `make artifacts` and build with the real `xla` crate,
//! then `cargo test -- --ignored`, to exercise them.

use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;

fn registry_or_skip() -> Option<Registry> {
    match Registry::discover() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn shufflenet_trains_loss_decreases() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().expect("pjrt cpu client");
    let exec = ModelExecutor::load(&client, &reg.dir, "shufflenet_s")
        .expect("load shufflenet_s");
    let mut state = exec.init_state(42).expect("init");
    let ds = SyntheticDataset::vision(1);
    let part = ds.partition(0);

    let mut losses = Vec::new();
    for step in 0..80 {
        let (x, y) = ds.batch(&part, step, exec.meta.batch);
        let loss = exec.train_step(&mut state, &x, &y).expect("train step");
        assert!(loss.is_finite(), "loss diverged at step {step}");
        losses.push(loss);
    }
    let first10: f64 = losses[..10]
        .iter()
        .map(|&l| f64::from(l))
        .sum::<f64>()
        / 10.0;
    let last10: f64 = losses[70..]
        .iter()
        .map(|&l| f64::from(l))
        .sum::<f64>()
        / 10.0;
    // random-guess CE for 64 classes is ln(64) ≈ 4.16; training on a
    // skewed non-IID partition must pull clearly below both that and
    // the starting loss
    assert!(
        last10 < 0.88 * first10 && last10 < 3.6,
        "loss must decrease: first10 {first10}, last10 {last10}"
    );
    assert_eq!(state.steps, 80);
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn eval_step_counts_correct_in_range() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "mobilenet_s").unwrap();
    let state = exec.init_state(0).unwrap();
    let ds = SyntheticDataset::vision(2);
    let (x, y) = ds.eval_batch(0, exec.meta.batch);
    let (loss, correct) = exec.eval_step(&state, &x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(correct >= 0.0 && correct <= exec.meta.batch as f32);
    assert_eq!(correct.fract(), 0.0, "count must be integral");
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn params_host_roundtrip_lossless() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec = ModelExecutor::load(&client, &reg.dir, "resnet_s").unwrap();
    let host = exec.init_host_params(7);
    let state = exec.state_from_host(&host).unwrap();
    let back = exec.state_to_host(&state).unwrap();
    assert_eq!(host.len(), back.len());
    for (a, b) in host.iter().zip(&back) {
        assert_eq!(a, b, "device round-trip must be bit-exact");
    }
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn training_is_deterministic_given_seed() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    let exec =
        ModelExecutor::load(&client, &reg.dir, "shufflenet_s").unwrap();
    let ds = SyntheticDataset::vision(3);
    let part = ds.partition(5);
    let mut run = || -> Vec<f32> {
        let mut state = exec.init_state(11).unwrap();
        (0..5)
            .map(|step| {
                let (x, y) = ds.batch(&part, step, exec.meta.batch);
                exec.train_step(&mut state, &x, &y).unwrap()
            })
            .collect()
    };
    assert_eq!(run(), run());
}

#[test]
#[ignore = "needs artifacts/*.hlo.txt (`make artifacts`) + real xla PJRT bindings; the offline build ships the stub in src/xla.rs"]
fn all_three_models_load_and_step() {
    let Some(reg) = registry_or_skip() else { return };
    let client = RuntimeClient::cpu().unwrap();
    for model in ["resnet_s", "mobilenet_s", "shufflenet_s"] {
        let exec = ModelExecutor::load(&client, &reg.dir, model).unwrap();
        let mut state = exec.init_state(1).unwrap();
        let ds = if exec.meta.task == "speech" {
            SyntheticDataset::speech(1)
        } else {
            SyntheticDataset::vision(1)
        };
        assert_eq!(ds.num_classes, exec.meta.num_classes, "{model}");
        let part = ds.partition(0);
        let (x, y) = ds.batch(&part, 0, exec.meta.batch);
        let loss = exec.train_step(&mut state, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{model}: loss {loss}");
    }
}
