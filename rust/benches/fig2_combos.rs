//! Fig 2a/2b: per-core-combination latency/energy/power on Pixel 3 for
//! ResNet-34 (scales) and ShuffleNet (anti-scales) — plus the same sweep
//! on every other device as supplementary rows.

use swan::soc::device::DeviceId;
use swan::workload::{load_or_builtin, WorkloadName};

fn main() {
    for (fig, wl) in [
        ("2a", WorkloadName::Resnet34),
        ("2b", WorkloadName::ShufflenetV2),
    ] {
        let w = load_or_builtin(wl, "artifacts");
        let (_rows, table) =
            swan::report::fig2_combo_rows(DeviceId::Pixel3, &w);
        println!("-- Figure {fig} --");
        table.emit().expect("emit");
    }
    // supplementary: the same sweep on every other device
    for dev in [DeviceId::S10e, DeviceId::OnePlus8, DeviceId::TabS6,
                DeviceId::Mi10] {
        let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
        let (_rows, table) = swan::report::fig2_combo_rows(dev, &w);
        table.emit().expect("emit");
    }
}
