//! Fig 3: PCMark score with vs without background (greedy) training.

fn main() {
    let (_rows, table) = swan::report::fig3_rows("artifacts");
    table.emit().expect("emit");
}
