//! Table 3: PCMark impact of background training, baseline vs Swan
//! (controller migrating under a live PCMark session).

fn main() {
    let t0 = std::time::Instant::now();
    let (_rows, table) = swan::report::table3_rows("artifacts");
    table.emit().expect("emit");
    println!("(computed in {:.2}s)", t0.elapsed().as_secs_f64());
}
