//! Fleet kernel throughput: devices-stepped/sec on the 100k-device
//! `city` scenario across shard counts, plus the resharding-determinism
//! check (every shard count must produce a bit-identical aggregate
//! digest). Pass `--small` to run the 2k-device smoke scenario instead.

use swan::fl::FlArm;
use swan::fleet::{run_scenario, ScenarioSpec};
use swan::report::fleet_table;
use swan::util::bench::BenchSet;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let small = args.iter().any(|a| a == "--small");
    let key = if small { "smoke" } else { "city" };
    let spec = ScenarioSpec::builtin(key).expect("builtin scenario");
    println!(
        "fleet_throughput: scenario '{}' — {} devices × {} rounds, \
         {} clients/round",
        spec.name, spec.devices, spec.rounds, spec.clients_per_round
    );

    let mut set = BenchSet::new("fleet_throughput");
    let mut outcomes = Vec::new();
    let mut digests: Vec<(usize, String)> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let out = run_scenario(&spec, shards, FlArm::Swan).expect("fleet run");
        set.record(
            &format!("devices_stepped_per_sec_{shards}shard"),
            out.devices_stepped_per_sec(),
            "dev/s",
        );
        set.record(
            &format!("steps_per_sec_{shards}shard"),
            out.steps_per_sec(),
            "steps/s",
        );
        set.record(&format!("wall_s_{shards}shard"), out.wall_s, "s");
        digests.push((shards, out.digest()));
        outcomes.push(out);
    }

    let (base_shards, base_digest) = digests[0].clone();
    for (shards, digest) in &digests[1..] {
        assert_eq!(
            digest, &base_digest,
            "{shards}-shard aggregates diverged from {base_shards}-shard"
        );
    }
    println!(
        "determinism: shard counts {:?} all produced digest {base_digest}",
        digests.iter().map(|(s, _)| *s).collect::<Vec<_>>()
    );

    // baseline arm for the comparison table
    let base = run_scenario(&spec, 4, FlArm::Baseline).expect("fleet run");
    outcomes.push(base);
    fleet_table(&outcomes).emit().expect("emit");
    set.write_csv().expect("csv");
}
