//! Fleet kernel throughput: devices-stepped/sec on the 100k-device
//! `city` scenario across shard counts, for BOTH kernels — the PR 1
//! message-passing `ShardedEventLoop` (reference) and the PR 2
//! struct-of-arrays `SoaFleet` — plus the determinism check: every
//! kernel × shard count must reproduce one bit-identical aggregate
//! digest, or the harness (and this bench) fails. Emits the
//! `BENCH_fleet.json` perf-trajectory record and a machine-parseable
//! `BENCH_fleet {…}` one-liner. Pass `--small` for the 2k-device smoke
//! scenario (the CI bench-smoke job's configuration), or `--million`
//! for the standing million-device SoA tier (reference kernel skipped —
//! at that scale it is the bottleneck being measured around).

use swan::fl::FlArm;
use swan::fleet::{run_fleet_bench, run_scenario, ScenarioSpec};
use swan::report::fleet_table;
use swan::util::bench::{BenchSet, Measurement};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let small = args.iter().any(|a| a == "--small");
    let million = args.iter().any(|a| a == "--million");
    let key = if million {
        "million"
    } else if small {
        "smoke"
    } else {
        "city"
    };
    let spec = ScenarioSpec::builtin(key).expect("builtin scenario");
    println!(
        "fleet_throughput: scenario '{}' — {} devices × {} rounds, \
         {} clients/round",
        spec.name, spec.devices, spec.rounds, spec.clients_per_round
    );

    let shard_counts: &[usize] =
        if million { &[4, 8] } else { &[1, 2, 4, 8] };
    let obs = if args.iter().any(|a| a == "--events") {
        swan::obs::Obs::stderr()
    } else {
        swan::obs::Obs::off()
    };
    let report =
        run_fleet_bench(&spec, shard_counts, FlArm::Swan, !million, &obs)
            .expect("fleet bench (fails on determinism violation)");

    let mut set = BenchSet::new("fleet_throughput");
    for out in report.reference.iter().chain(report.soa.iter()) {
        // one drive = one sample; throughput flows through the shared
        // Measurement::per_sec reporting
        let wall = Measurement {
            name: format!("{}_{}shard_wall", out.kernel, out.shards),
            samples: vec![out.wall_s],
        };
        set.record(
            &format!(
                "{}_{}shard_devices_stepped_per_sec",
                out.kernel, out.shards
            ),
            wall.per_sec(out.devices_stepped() as f64),
            "dev/s",
        );
        set.record(
            &format!("{}_{}shard_wall_s", out.kernel, out.shards),
            out.wall_s,
            "s",
        );
    }
    for (shards, ratio) in report.speedup_same_shards() {
        println!("speedup vs reference @ {shards} shards: {ratio:.2}x");
    }
    if let Some(ratio) = report.speedup_best() {
        println!("speedup best-vs-best: {ratio:.2}x");
    }
    let kernels = if million {
        "{soa}"
    } else {
        "{event_loop, soa}"
    };
    println!(
        "determinism: kernels {kernels} × shards {shard_counts:?} \
         all produced digest {}",
        report.digest
    );

    // baseline arm for the comparison table
    let base = run_scenario(&spec, 4, FlArm::Baseline).expect("fleet run");
    let mut outcomes = report.soa.clone();
    outcomes.push(base);
    fleet_table(&outcomes).emit().expect("emit");
    set.write_csv().expect("csv");

    let path = report.write_json("BENCH_fleet.json").expect("bench json");
    println!("wrote {}", path.display());
    println!("{}", report.one_line());
}
