//! Table 4: FL time-to-accuracy speedup and energy efficiency for the
//! three tasks. Bench-scale configuration (small fleet, short horizon)
//! — the full run is `cargo run --release --example federated`.

use swan::fl::{FlArm, FlConfig, FlSim};
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;
use swan::util::table::{fmt_ratio, Table};
use swan::workload::{load_or_builtin, WorkloadName};

/// `--fleet` fast path: the Table-4 systems ratios (time + energy) from
/// the sharded fleet kernel — no artifacts or PJRT needed, and it scales
/// to far larger fleets than the numerics path.
fn fleet_fast_path() {
    let mut table = Table::new(
        "Table 4 (fleet fast path) — systems time/energy ratios",
        &["model", "time_speedup", "energy_eff", "swan_online_last", "base_online_last"],
    );
    for (model, wl) in [
        ("mobilenet", WorkloadName::MobilenetV2),
        ("shufflenet", WorkloadName::ShufflenetV2),
        ("resnet34", WorkloadName::Resnet34),
    ] {
        let spec = swan::fleet::ScenarioSpec {
            workload: wl,
            ..swan::fleet::ScenarioSpec::builtin("smoke").unwrap()
        };
        let swan_out =
            swan::fleet::run_scenario(&spec, 4, FlArm::Swan).expect("fleet");
        let base_out = swan::fleet::run_scenario(&spec, 4, FlArm::Baseline)
            .expect("fleet");
        table.row(&[
            model.to_string(),
            fmt_ratio(base_out.total_time_s / swan_out.total_time_s.max(1e-9)),
            fmt_ratio(
                base_out.total_energy_j / swan_out.total_energy_j.max(1e-9),
            ),
            swan_out.online_last().to_string(),
            base_out.online_last().to_string(),
        ]);
    }
    table.emit().expect("emit");
}

/// `--serve` path: the Table-4 ratios with real SGD routed through the
/// serve coordinator (softmax-probe numerics, no artifacts/PJRT). The
/// harness asserts every row's run is bit-identical to the direct
/// oracle before it lands in the table.
fn serve_path() {
    let cfg = FlConfig {
        seed: 5,
        raw_traces: 8,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 3,
        rounds: 10,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 2_000.0,
        server_overhead_s: 2.0,
    };
    let mut table = Table::new(
        "Table 4 (serve-routed) — FL time-to-accuracy and energy",
        &["model", "tta_speedup", "energy_eff", "swan_best_acc", "base_best_acc"],
    );
    for (model, wl) in [
        ("mobilenet", WorkloadName::MobilenetV2),
        ("shufflenet", WorkloadName::ShufflenetV2),
        ("resnet34", WorkloadName::Resnet34),
    ] {
        let run = |arm: FlArm| {
            swan::fleet::run_fl_bench(
                &cfg,
                arm,
                wl,
                2,
                false,
                &swan::obs::Obs::off(),
            )
            .expect("serve-routed FL run")
            .inproc // digest-identical to the oracle
        };
        let swan_out = run(FlArm::Swan);
        let base_out = run(FlArm::Baseline);
        let target =
            swan_out.best_accuracy().min(base_out.best_accuracy());
        let tta = match (
            swan_out.time_to_accuracy(target),
            base_out.time_to_accuracy(target),
        ) {
            (Some(a), Some(b)) => b / a.max(1.0),
            _ => f64::NAN,
        };
        table.row(&[
            model.to_string(),
            fmt_ratio(tta),
            fmt_ratio(
                base_out.total_energy_j / swan_out.total_energy_j.max(1.0),
            ),
            format!("{:.3}", swan_out.best_accuracy()),
            format!("{:.3}", base_out.best_accuracy()),
        ]);
    }
    table.emit().expect("emit");
}

fn main() {
    if std::env::args().any(|a| a == "--fleet") {
        fleet_fast_path();
        return;
    }
    if std::env::args().any(|a| a == "--serve") {
        serve_path();
        return;
    }
    let Ok(reg) = Registry::discover() else {
        println!(
            "artifacts not built; run `make artifacts` (or pass --fleet \
             / --serve)"
        );
        return;
    };
    let client = RuntimeClient::cpu().expect("pjrt");
    let cfg = FlConfig {
        seed: 5,
        raw_traces: 8,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 3,
        rounds: 10,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 2_000.0,
        server_overhead_s: 2.0,
    };
    let mut table = Table::new(
        "Table 4 — FL time-to-accuracy and energy (bench scale)",
        &["model", "tta_speedup", "energy_eff", "swan_best_acc", "base_best_acc"],
    );
    for (model, paper) in [
        ("mobilenet_s", WorkloadName::MobilenetV2),
        ("shufflenet_s", WorkloadName::ShufflenetV2),
        ("resnet_s", WorkloadName::Resnet34),
    ] {
        let exec = ModelExecutor::load(&client, &reg.dir, model).unwrap();
        let workload = load_or_builtin(paper, "artifacts");
        let mut run = |arm: FlArm| {
            let ds = if exec.meta.task == "speech" {
                SyntheticDataset::speech(cfg.seed)
            } else {
                SyntheticDataset::vision(cfg.seed)
            };
            let mut sim =
                FlSim::new(cfg.clone(), arm, ds, &workload).unwrap();
            sim.run(&exec).unwrap()
        };
        let swan = run(FlArm::Swan);
        let base = run(FlArm::Baseline);
        let target = swan.best_accuracy().min(base.best_accuracy());
        let tta = match (
            swan.time_to_accuracy(target),
            base.time_to_accuracy(target),
        ) {
            (Some(a), Some(b)) => b / a.max(1.0),
            _ => f64::NAN,
        };
        table.row(&[
            model.to_string(),
            fmt_ratio(tta),
            fmt_ratio(base.total_energy_j / swan.total_energy_j.max(1.0)),
            format!("{:.3}", swan.best_accuracy()),
            format!("{:.3}", base.best_accuracy()),
        ]);
    }
    table.emit().expect("emit");
}
