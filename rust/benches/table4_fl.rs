//! Table 4: FL time-to-accuracy speedup and energy efficiency for the
//! three tasks. Bench-scale configuration (small fleet, short horizon)
//! — the full run is `cargo run --release --example federated`.

use swan::fl::{FlArm, FlConfig, FlSim};
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;
use swan::util::table::{fmt_ratio, Table};
use swan::workload::{load_or_builtin, WorkloadName};

fn main() {
    let Ok(reg) = Registry::discover() else {
        println!("artifacts not built; run `make artifacts`");
        return;
    };
    let client = RuntimeClient::cpu().expect("pjrt");
    let cfg = FlConfig {
        seed: 5,
        raw_traces: 8,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 3,
        rounds: 10,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 2_000.0,
        server_overhead_s: 2.0,
    };
    let mut table = Table::new(
        "Table 4 — FL time-to-accuracy and energy (bench scale)",
        &["model", "tta_speedup", "energy_eff", "swan_best_acc", "base_best_acc"],
    );
    for (model, paper) in [
        ("mobilenet_s", WorkloadName::MobilenetV2),
        ("shufflenet_s", WorkloadName::ShufflenetV2),
        ("resnet_s", WorkloadName::Resnet34),
    ] {
        let exec = ModelExecutor::load(&client, &reg.dir, model).unwrap();
        let workload = load_or_builtin(paper, "artifacts");
        let mut run = |arm: FlArm| {
            let ds = if exec.meta.task == "speech" {
                SyntheticDataset::speech(cfg.seed)
            } else {
                SyntheticDataset::vision(cfg.seed)
            };
            let mut sim =
                FlSim::new(cfg.clone(), arm, ds, &workload).unwrap();
            sim.run(&exec).unwrap()
        };
        let swan = run(FlArm::Swan);
        let base = run(FlArm::Baseline);
        let target = swan.best_accuracy().min(base.best_accuracy());
        let tta = match (
            swan.time_to_accuracy(target),
            base.time_to_accuracy(target),
        ) {
            (Some(a), Some(b)) => b / a.max(1.0),
            _ => f64::NAN,
        };
        table.row(&[
            model.to_string(),
            fmt_ratio(tta),
            fmt_ratio(base.total_energy_j / swan.total_energy_j.max(1.0)),
            format!("{:.3}", swan.best_accuracy()),
            format!("{:.3}", base.best_accuracy()),
        ]);
    }
    table.emit().expect("emit");
}
