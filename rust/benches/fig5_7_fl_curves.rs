//! Figs 5/6/7: FL accuracy-vs-time and clients-online-per-round series
//! for ShuffleNet / MobileNet / ResNet-34, Swan vs baseline.
//! Bench-scale; CSV series land in target/reports/.

use swan::fl::{FlArm, FlConfig, FlSim};
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::train::data::SyntheticDataset;
use swan::workload::{load_or_builtin, WorkloadName};

/// `--fleet` fast path: Figs 5b/6b/7b (clients-online-per-round) from
/// the sharded fleet kernel — availability is numerics-independent, so
/// no artifacts or PJRT are needed and the horizon can be fleet-scale.
fn fleet_fast_path() {
    std::fs::create_dir_all("target/reports").unwrap();
    for (fig, wl) in [
        ("fig5", WorkloadName::ShufflenetV2),
        ("fig6", WorkloadName::MobilenetV2),
        ("fig7", WorkloadName::Resnet34),
    ] {
        let spec = swan::fleet::ScenarioSpec {
            workload: wl,
            rounds: 2_000,
            daily_credit_j: 400.0, // tight budget: makes Fig b visible
            ..swan::fleet::ScenarioSpec::builtin("smoke").unwrap()
        };
        println!("== {fig} (fleet): {:?} ==", wl);
        for arm in [FlArm::Swan, FlArm::Baseline] {
            let out = swan::fleet::run_scenario(&spec, 4, arm)
                .expect("fleet run");
            let mut online = String::from("round,online\n");
            for (r, n) in &out.online_per_round {
                online.push_str(&format!("{r},{n}\n"));
            }
            std::fs::write(
                format!("target/reports/{fig}b_{}_fleet.csv", out.arm),
                online,
            )
            .unwrap();
            println!(
                "  {:9} online {} -> {} over {} rounds \
                 ({:.0} devices-stepped/s)",
                out.arm,
                out.online_first(),
                out.online_last(),
                out.rounds_run,
                out.devices_stepped_per_sec()
            );
        }
    }
}

/// `--serve` path: Figs 5a/6a/7a with real SGD routed through the
/// serve coordinator — the softmax probe supplies numerics, so no
/// artifacts or PJRT are needed, and the harness asserts bit-identity
/// against the direct oracle before any CSV is written.
fn serve_path() {
    std::fs::create_dir_all("target/reports").unwrap();
    let cfg = FlConfig {
        seed: 9,
        raw_traces: 8,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 3,
        rounds: 12,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 1_500.0,
        server_overhead_s: 2.0,
    };
    for (fig, wl) in [
        ("fig5", WorkloadName::ShufflenetV2),
        ("fig6", WorkloadName::MobilenetV2),
        ("fig7", WorkloadName::Resnet34),
    ] {
        println!("== {fig} (serve-routed): {:?} ==", wl);
        for arm in [FlArm::Swan, FlArm::Baseline] {
            let report = swan::fleet::run_fl_bench(
                &cfg,
                arm,
                wl,
                2,
                false,
                &swan::obs::Obs::off(),
            )
            .expect("serve-routed FL run");
            let out = &report.inproc; // digest-identical to the oracle
            println!(
                "  {:9} vt={:7.1}s energy={:8.0}J best_acc={:.3} \
                 digest={}",
                arm.name(),
                out.total_time_s,
                out.total_energy_j,
                out.best_accuracy(),
                report.digest
            );
            std::fs::write(
                format!("target/reports/{fig}a_{}_serve.csv", arm.name()),
                out.accuracy_curve.to_csv("accuracy"),
            )
            .unwrap();
            let mut online = String::from("round,online\n");
            for (r, n) in &out.online_per_round {
                online.push_str(&format!("{r},{n}\n"));
            }
            std::fs::write(
                format!("target/reports/{fig}b_{}_serve.csv", arm.name()),
                online,
            )
            .unwrap();
        }
    }
}

fn main() {
    if std::env::args().any(|a| a == "--fleet") {
        fleet_fast_path();
        return;
    }
    if std::env::args().any(|a| a == "--serve") {
        serve_path();
        return;
    }
    let Ok(reg) = Registry::discover() else {
        println!(
            "artifacts not built; run `make artifacts` (or pass --fleet \
             / --serve)"
        );
        return;
    };
    let client = RuntimeClient::cpu().expect("pjrt");
    let cfg = FlConfig {
        seed: 9,
        raw_traces: 8,
        quality_traces: 2,
        clients_per_round: 3,
        local_steps: 3,
        rounds: 12,
        eval_every: 2,
        eval_batches: 2,
        daily_credit_j: 1_500.0, // tight budget: makes Fig b visible
        server_overhead_s: 2.0,
    };
    std::fs::create_dir_all("target/reports").unwrap();
    for (fig, model, paper) in [
        ("fig5", "shufflenet_s", WorkloadName::ShufflenetV2),
        ("fig6", "mobilenet_s", WorkloadName::MobilenetV2),
        ("fig7", "resnet_s", WorkloadName::Resnet34),
    ] {
        let exec = ModelExecutor::load(&client, &reg.dir, model).unwrap();
        let workload = load_or_builtin(paper, "artifacts");
        println!("== {fig}: {model} ==");
        for arm in [FlArm::Swan, FlArm::Baseline] {
            let ds = if exec.meta.task == "speech" {
                SyntheticDataset::speech(cfg.seed)
            } else {
                SyntheticDataset::vision(cfg.seed)
            };
            let mut sim =
                FlSim::new(cfg.clone(), arm, ds, &workload).unwrap();
            let out = sim.run(&exec).unwrap();
            println!(
                "  {:9} vt={:7.1}s energy={:8.0}J best_acc={:.3} online(last)={}",
                arm.name(),
                out.total_time_s,
                out.total_energy_j,
                out.best_accuracy(),
                out.online_per_round.last().map(|x| x.1).unwrap_or(0)
            );
            std::fs::write(
                format!("target/reports/{fig}a_{}.csv", arm.name()),
                out.accuracy_curve.to_csv("accuracy"),
            )
            .unwrap();
            let mut online = String::from("round,online\n");
            for (r, n) in &out.online_per_round {
                online.push_str(&format!("{r},{n}\n"));
            }
            std::fs::write(
                format!("target/reports/{fig}b_{}_shorthorizon.csv", arm.name()),
                online,
            )
            .unwrap();

            // Fig b proper: week-scale availability horizon (systems
            // only — availability is independent of model values)
            let ds2 = if exec.meta.task == "speech" {
                SyntheticDataset::speech(cfg.seed)
            } else {
                SyntheticDataset::vision(cfg.seed)
            };
            let horizon_cfg = FlConfig {
                quality_traces: 4,
                raw_traces: 16,
                clients_per_round: 20,
                daily_credit_j: 400.0,
                ..cfg.clone()
            };
            let mut sim2 =
                FlSim::new(horizon_cfg, arm, ds2, &workload).unwrap();
            let out2 = sim2
                .run_systems_only(4000)
                .expect("systems-only horizon run");
            let mut online2 = String::from("round,online\n");
            for (r, n) in &out2.online_per_round {
                online2.push_str(&format!("{r},{n}\n"));
            }
            std::fs::write(
                format!("target/reports/{fig}b_{}.csv", arm.name()),
                online2,
            )
            .unwrap();
            let first = out2.online_per_round.first().map(|x| x.1).unwrap_or(0);
            let last = out2.online_per_round.last().map(|x| x.1).unwrap_or(0);
            println!(
                "  {:9} fig-b horizon: online {} -> {} over {} rounds",
                arm.name(),
                first,
                last,
                out2.rounds_run
            );
        }
    }
}
