//! Fig 1b: per-core 512×512 matmul latency across the five SoCs (+GPU),
//! plus the REAL matmul512 artifact timed through PJRT on the host
//! (the compute the simulator's numbers stand in for).

use swan::runtime::{Registry, RuntimeClient};
use swan::util::bench::BenchSet;

fn main() {
    // simulated per-core rows (the figure itself)
    let (_rows, table) = swan::report::fig1b_matmul_rows();
    table.emit().expect("emit");

    // host-measured PJRT execution of the actual artifact
    let mut set = BenchSet::new("fig1b_matmul_host").with_samples(3, 10);
    if let Ok(reg) = Registry::discover() {
        let client = RuntimeClient::cpu().expect("pjrt");
        let exe = client
            .compile_hlo_file(reg.dir.join("matmul512.hlo.txt"))
            .expect("compile");
        let x: Vec<f32> = (0..512 * 512).map(|i| (i % 13) as f32).collect();
        let y: Vec<f32> = (0..512 * 512).map(|i| (i % 7) as f32).collect();
        let xb = client.upload_f32(&x, &[512, 512]).unwrap();
        let yb = client.upload_f32(&y, &[512, 512]).unwrap();
        set.bench("pjrt_matmul512_host_cpu", || {
            let out = exe.execute_b(&[&xb, &yb]).expect("exec");
            std::hint::black_box(&out[0][0]);
        });
    } else {
        println!("(artifacts not built; host measurement skipped)");
    }
    set.write_csv().expect("csv");
}
