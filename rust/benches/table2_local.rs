//! Table 2: local speedup + energy efficiency over the greedy baseline,
//! 5 devices × 3 models, via the full §4.2 exploration pipeline.

fn main() {
    let t0 = std::time::Instant::now();
    let (_rows, table) = swan::report::table2_rows("artifacts");
    table.emit().expect("emit");
    println!("(computed in {:.2}s)", t0.elapsed().as_secs_f64());
}
