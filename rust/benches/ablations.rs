//! Ablations on Swan's design choices (DESIGN.md §4 extras):
//!
//! 1. pruning OFF — does the controller thrash / land on dominated
//!    choices under interference?
//! 2. migration OFF — Swan picks the best idle choice but never moves:
//!    what happens to effective step latency under interference?
//! 3. cost-order variants — latency-only ordering vs the paper's
//!    relinquish-cost order: PCMark impact of the downgrade target.

use swan::sim::interference::SessionGenerator;
use swan::sim::pcmark::score_impact_percent;
use swan::sim::SimPhone;
use swan::soc::device::{device, DeviceId};
use swan::soc::exec_model::{estimate, ExecutionContext};
use swan::swan::choice::enumerate_choices;
use swan::swan::controller::{Controller, ControllerConfig};
use swan::swan::profile::ChoiceProfile;
use swan::swan::prune::prune_dominated;
use swan::util::table::Table;
use swan::workload::{load_or_builtin, WorkloadName};

fn profiles(dev: DeviceId, wl: WorkloadName) -> Vec<ChoiceProfile> {
    let d = device(dev);
    let w = load_or_builtin(wl, "artifacts");
    let ctx = ExecutionContext::exclusive(d.n_cores());
    enumerate_choices(&d)
        .into_iter()
        .map(|ch| {
            let est = estimate(&d, &w, &ch.cores, &ctx);
            ChoiceProfile {
                choice: ch,
                latency_s: est.latency_s,
                energy_j: est.energy_j,
                power_w: est.avg_power_w,
                steps_measured: 5,
            }
        })
        .collect()
}

fn main() {
    let mut table = Table::new(
        "Ablations — pruning, migration, cost order",
        &["ablation", "metric", "value"],
    );

    // 1. pruning: chain length with/without, and whether the unpruned
    // chain contains dominated choices (slower AND costlier)
    for wl in [WorkloadName::Resnet34, WorkloadName::ShufflenetV2] {
        let profs = profiles(DeviceId::Pixel3, wl);
        let mut unpruned = profs.clone();
        unpruned.sort_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap());
        let pruned = prune_dominated(profs);
        table.row(&[
            format!("pruning ({wl:?})"),
            "chain length pruned/unpruned".into(),
            format!("{}/{}", pruned.len(), unpruned.len()),
        ]);
    }

    // 2. migration off: mean effective step latency under an endless
    // heavy session, migrating vs pinned-to-best
    let d = device(DeviceId::Pixel3);
    let w = load_or_builtin(WorkloadName::Resnet34, "artifacts");
    let chain = prune_dominated(profiles(DeviceId::Pixel3, WorkloadName::Resnet34));
    for migrate in [true, false] {
        let mut phone = SimPhone::new(d.clone(), 21)
            .with_sessions(SessionGenerator::new(22, 1e-6, 1e15, 1.0));
        phone.idle(1.0);
        let mut ctl = Controller::new(chain.clone(), ControllerConfig::default());
        let mut total = 0.0;
        let n = 60;
        for _ in 0..n {
            let cores = ctl.current().choice.cores.clone();
            let est = phone.run_train_step(&w, &cores);
            total += est.latency_s;
            if migrate {
                ctl.observe_step(est.latency_s);
            }
        }
        table.row(&[
            format!("migration={migrate}"),
            "mean step latency under interference (s)".into(),
            format!("{:.3}", total / n as f64),
        ]);
    }

    // 3. cost order: downgrade-by-cost vs downgrade-by-latency-only —
    // PCMark impact of the first downgrade target
    let profs = profiles(DeviceId::OnePlus8, WorkloadName::Resnet34);
    let d8 = device(DeviceId::OnePlus8);
    let pruned = prune_dominated(profs.clone());
    if pruned.len() > 1 {
        let cost_target = &pruned[1]; // paper's order
        let mut by_lat = profs;
        by_lat.sort_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap());
        let lat_target = &by_lat[1]; // next-fastest regardless of cost
        table.row(&[
            "cost-order downgrade".into(),
            format!("target {} PCMark impact %", cost_target.choice.label()),
            format!(
                "{:.1}",
                score_impact_percent(&d8, &cost_target.choice.cores)
            ),
        ]);
        table.row(&[
            "latency-order downgrade".into(),
            format!("target {} PCMark impact %", lat_target.choice.label()),
            format!(
                "{:.1}",
                score_impact_percent(&d8, &lat_target.choice.cores)
            ),
        ]);
    }

    table.emit().expect("emit");
}
