//! §Perf: the L3 hot paths, measured.
//!
//! - real PJRT train/eval step wall time per model (the end-to-end
//!   numerics cost the FL harness pays per selected client);
//! - parameter upload/download (FedAvg's per-round host round-trip);
//! - the pure-simulation hot loop (exec_model::estimate), which every
//!   explorer/controller/FL-policy call goes through;
//! - FedAvg aggregation.

use swan::fl::fedavg;
use swan::runtime::{ModelExecutor, Registry, RuntimeClient};
use swan::soc::device::{device, DeviceId};
use swan::soc::exec_model::{estimate, ExecutionContext};
use swan::train::data::SyntheticDataset;
use swan::util::bench::BenchSet;
use swan::workload::{load_or_builtin, WorkloadName};

fn main() {
    let mut set = BenchSet::new("perf_hotpath").with_samples(3, 12);

    // pure-sim estimate (called O(choices × steps) everywhere)
    let d = device(DeviceId::S10e);
    let w = load_or_builtin(WorkloadName::ShufflenetV2, "artifacts");
    let ctx = ExecutionContext::exclusive(d.n_cores());
    set.bench("exec_model_estimate_337op", || {
        std::hint::black_box(estimate(&d, &w, &[4, 5, 6, 7], &ctx));
    });

    let Ok(reg) = Registry::discover() else {
        println!("(artifacts not built; runtime benches skipped)");
        set.write_csv().unwrap();
        return;
    };
    let client = RuntimeClient::cpu().expect("pjrt");
    for model in ["resnet_s", "mobilenet_s", "shufflenet_s"] {
        let exec = ModelExecutor::load(&client, &reg.dir, model).unwrap();
        let ds = if exec.meta.task == "speech" {
            SyntheticDataset::speech(1)
        } else {
            SyntheticDataset::vision(1)
        };
        let part = ds.partition(0);
        let (x, y) = ds.batch(&part, 0, exec.meta.batch);
        let mut state = exec.init_state(0).unwrap();
        set.bench(&format!("pjrt_train_step_{model}"), || {
            let loss = exec.train_step(&mut state, &x, &y).unwrap();
            std::hint::black_box(loss);
        });
        set.bench(&format!("pjrt_eval_step_{model}"), || {
            let out = exec.eval_step(&state, &x, &y).unwrap();
            std::hint::black_box(out);
        });
        set.bench(&format!("params_download_{model}"), || {
            let host = exec.state_to_host(&state).unwrap();
            std::hint::black_box(host.len());
        });
        let host = exec.state_to_host(&state).unwrap();
        set.bench(&format!("params_upload_{model}"), || {
            let s = exec.state_from_host(&host).unwrap();
            std::hint::black_box(s.steps);
        });
        // FedAvg over 5 clients' parameters
        let updates: Vec<(Vec<Vec<f32>>, f64)> =
            (0..5).map(|i| (host.clone(), 1.0 + i as f64)).collect();
        set.bench(&format!("fedavg_5clients_{model}"), || {
            std::hint::black_box(fedavg(&updates).unwrap().len());
        });
    }
    set.write_csv().unwrap();
}
