# L2 model tests: shapes, determinism, learning, and spec/apply agreement.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(name, seed=0):
    cfg = M.MODELS[name]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        (M.BATCH,) + cfg["input_shape"]).astype("float32"))
    y = jnp.asarray(rng.integers(
        0, cfg["num_classes"], size=(M.BATCH,)).astype("int32"))
    return x, y


@pytest.mark.parametrize("name", list(M.MODELS))
def test_specs_sorted_and_unique(name):
    specs = M.MODELS[name]["specs"]()
    names = [s["name"] for s in specs]
    assert names == sorted(names)
    assert len(names) == len(set(names))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_specs_valid_inits(name):
    for s in M.MODELS[name]["specs"]():
        assert s["init"] in ("he", "ones", "zeros")
        if s["init"] == "he":
            assert s["fan_in"] > 0
        assert all(d > 0 for d in s["shape"])


@pytest.mark.parametrize("name", list(M.MODELS))
def test_apply_output_shape(name):
    cfg = M.MODELS[name]
    params = dict(zip([s["name"] for s in cfg["specs"]()],
                      M.init_params(name)))
    x, _ = _batch(name)
    logits = cfg["apply"](params, x)
    assert logits.shape == (M.BATCH, cfg["num_classes"])
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_train_step_signature(name):
    cfg = M.MODELS[name]
    specs = cfg["specs"]()
    names = [s["name"] for s in specs]
    params = M.init_params(name)
    x, y = _batch(name)
    out = M.make_train_step(cfg["apply"], names, 0.05)(*params, x, y)
    assert len(out) == len(specs) + 1
    for new, old in zip(out[:-1], params):
        assert new.shape == old.shape
    assert out[-1].shape == ()


@pytest.mark.parametrize("name", list(M.MODELS))
def test_train_step_reduces_loss_on_fixed_batch(name):
    cfg = M.MODELS[name]
    names = [s["name"] for s in cfg["specs"]()]
    ts = jax.jit(M.make_train_step(cfg["apply"], names, M.LEARNING_RATE))
    params = M.init_params(name)
    x, y = _batch(name)
    loss0 = float(ts(*params, x, y)[-1])
    p = params
    for _ in range(12):
        out = ts(*p, x, y)
        p = list(out[:-1])
    assert float(out[-1]) < 0.7 * loss0


@pytest.mark.parametrize("name", list(M.MODELS))
def test_eval_step_counts(name):
    cfg = M.MODELS[name]
    names = [s["name"] for s in cfg["specs"]()]
    params = M.init_params(name)
    x, y = _batch(name)
    loss, correct = M.make_eval_step(cfg["apply"], names)(*params, x, y)
    assert 0.0 <= float(correct) <= M.BATCH
    assert float(correct) == int(float(correct))
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_train_step_deterministic(name):
    cfg = M.MODELS[name]
    names = [s["name"] for s in cfg["specs"]()]
    ts = M.make_train_step(cfg["apply"], names, 0.05)
    params = M.init_params(name)
    x, y = _batch(name)
    a = ts(*params, x, y)
    b = ts(*params, x, y)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(u, v)


def test_cross_entropy_uniform_logits():
    logits = jnp.zeros((8, 10), jnp.float32)
    y = jnp.arange(8, dtype=jnp.int32) % 10
    np.testing.assert_allclose(
        M.cross_entropy(logits, y), np.log(10.0), rtol=1e-6)


def test_cross_entropy_perfect_prediction():
    y = jnp.arange(4, dtype=jnp.int32)
    logits = jax.nn.one_hot(y, 5) * 100.0
    assert float(M.cross_entropy(logits, y)) < 1e-3


def test_group_norm_normalizes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 4, 4, 16)).astype("float32") * 7 + 3)
    out = M.group_norm(x, jnp.ones(16), jnp.zeros(16), groups=8)
    m = float(jnp.mean(out))
    v = float(jnp.var(out))
    assert abs(m) < 0.1 and abs(v - 1.0) < 0.1


def test_channel_shuffle_is_permutation():
    x = jnp.arange(2 * 3 * 3 * 8, dtype=jnp.float32).reshape(2, 3, 3, 8)
    out = M.channel_shuffle(x, 2)
    assert sorted(np.asarray(out[0, 0, 0]).tolist()) == \
        sorted(np.asarray(x[0, 0, 0]).tolist())
    assert not np.array_equal(out, x)


def test_avg_pool2_constant_preserved():
    x = jnp.full((1, 8, 8, 3), 2.5, jnp.float32)
    out = M.avg_pool2(x)
    assert out.shape == (1, 4, 4, 3)
    np.testing.assert_allclose(out, 2.5)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_init_params_match_specs(name):
    specs = M.MODELS[name]["specs"]()
    params = M.init_params(name)
    assert len(params) == len(specs)
    for p, s in zip(params, specs):
        assert list(p.shape) == s["shape"]
        if s["init"] == "ones":
            np.testing.assert_array_equal(p, np.ones(s["shape"], "float32"))
        if s["init"] == "zeros":
            np.testing.assert_array_equal(p, np.zeros(s["shape"], "float32"))
