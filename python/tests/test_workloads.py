# Workload-descriptor tests: these JSONs parameterize the Rust SoC
# simulator, so their invariants ARE the paper's §3.1 premises.
import pytest

from compile import workloads as W


@pytest.fixture(scope="module")
def descs():
    return {name: fn() for name, fn in W.ALL_PAPER.items()}


def test_all_descriptors_well_formed(descs):
    for d in descs.values():
        assert d["total_flops"] > 0
        assert d["total_bytes"] > 0
        assert d["arithmetic_intensity"] > 0
        assert 0.0 <= d["memory_bound_byte_fraction"] <= 1.0
        for op in d["ops"]:
            assert op["flops"] >= 0 and op["bytes"] > 0
            assert op["kind"] in ("conv", "pw", "dw", "norm", "act",
                                  "pool", "add", "linear", "update")


def test_totals_are_op_sums(descs):
    for d in descs.values():
        assert abs(sum(o["flops"] for o in d["ops"]) - d["total_flops"]) < 1
        assert abs(sum(o["bytes"] for o in d["ops"]) - d["total_bytes"]) < 1


def test_resnet34_flops_ballpark(descs):
    """ResNet-34 on 32×32×1 is ≈ 0.6-1.5 GFLOP fwd per sample ⇒ batch-16
    train step (3× fwd) in the tens of GFLOPs."""
    tf = descs["resnet34"]["total_flops"]
    assert 1e10 < tf < 2e11


def test_depthwise_models_are_more_memory_bound(descs):
    """The §3.1 cache-thrashing argument: ShuffleNet/MobileNet move a far
    larger fraction of their bytes through memory-bound ops than ResNet."""
    rn = descs["resnet34"]
    for name in ("mobilenet_v2", "shufflenet_v2"):
        d = descs[name]
        # more of their traffic flows through memory-bound ops...
        assert (d["memory_bound_byte_fraction"]
                > rn["memory_bound_byte_fraction"])
        # ...and their overall arithmetic intensity is far lower
        assert rn["arithmetic_intensity"] > 5 * d["arithmetic_intensity"]


def test_resnet_has_highest_arithmetic_intensity(descs):
    assert (descs["resnet34"]["arithmetic_intensity"]
            > descs["mobilenet_v2"]["arithmetic_intensity"])
    assert (descs["resnet34"]["arithmetic_intensity"]
            > descs["shufflenet_v2"]["arithmetic_intensity"])


def test_matmul512_exact(descs):
    d = descs["matmul512"]
    assert d["total_flops"] == 2 * 512**3
    assert d["total_bytes"] == 4 * 3 * 512 * 512


def test_param_counts_ballpark(descs):
    # ResNet-34 ≈ 21M; MobileNetV2 ≈ 3-4M (600-way head); ShuffleNetV2 ≈ 2-3M
    assert 15e6 < descs["resnet34"]["param_scalars"] < 30e6
    assert 2e6 < descs["mobilenet_v2"]["param_scalars"] < 6e6
    assert 1e6 < descs["shufflenet_v2"]["param_scalars"] < 5e6


@pytest.mark.parametrize("name", ["resnet_s", "mobilenet_s", "shufflenet_s"])
def test_small_variants_well_formed(name):
    d = W.small_variant(name)
    assert d["total_flops"] > 0
    assert d["name"] == name
    kinds = {o["kind"] for o in d["ops"]}
    if name != "resnet_s":
        assert "dw" in kinds, "depthwise models must contain dw ops"


def test_small_variant_param_count_matches_model():
    """The walker's parameter accounting must agree with the real model."""
    import numpy as np
    from compile import model as M
    for name in ("resnet_s", "mobilenet_s", "shufflenet_s"):
        d = W.small_variant(name)
        true = sum(int(np.prod(s["shape"])) for s in M.MODELS[name]["specs"]())
        # walker skips biases/gn affine in some ops; allow 10% slack
        assert abs(d["param_scalars"] - true) / true < 0.10, name


def test_bwd_ops_double_fwd(descs):
    d = descs["resnet34"]
    fwd = [o for o in d["ops"] if not o["name"].endswith("#bwd")
           and o["name"] != "sgd_update"]
    bwd = [o for o in d["ops"] if o["name"].endswith("#bwd")]
    assert len(fwd) == len(bwd)
    assert abs(sum(o["flops"] for o in bwd)
               - 2 * sum(o["flops"] for o in fwd)) < 1
