# pytest: Pallas kernels vs pure-jnp refs — the CORE correctness signal.
# hypothesis sweeps shapes; every kernel is checked forward AND backward
# (the custom_vjp backward passes are themselves Pallas kernels).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype("float32"))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_forward_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(
        K.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 8, 8), (128, 128, 128), (129, 7, 255),
    (512, 512, 512), (16384, 144, 16), (3, 4096, 5),
])
def test_matmul_forward_shapes(m, k, n):
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    x, y = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(
        K.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(5, 7, 3), (64, 33, 17), (130, 130, 130)])
def test_matmul_grad_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x, y = _arr(rng, m, k), _arr(rng, k, n)

    def f_pallas(a, b):
        return jnp.sum(jnp.tanh(K.matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.tanh(ref.matmul_ref(a, b)))

    gp = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, y)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_matmul_zero_operand():
    x = jnp.zeros((17, 9), jnp.float32)
    y = jnp.ones((9, 5), jnp.float32)
    np.testing.assert_array_equal(K.matmul(x, y), jnp.zeros((17, 5)))


def test_matmul_identity():
    rng = np.random.default_rng(0)
    x = _arr(rng, 40, 40)
    eye = jnp.eye(40, dtype=jnp.float32)
    np.testing.assert_allclose(K.matmul(x, eye), x, rtol=1e-5, atol=1e-5)


def test_matmul_jit_consistency():
    rng = np.random.default_rng(3)
    x, y = _arr(rng, 33, 45), _arr(rng, 45, 21)
    np.testing.assert_allclose(
        jax.jit(K.matmul)(x, y), K.matmul(x, y), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# depthwise3x3
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 4),
    h=st.integers(3, 18),
    w=st.integers(3, 18),
    c=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_forward_hypothesis(n, h, w, c, seed):
    rng = np.random.default_rng(seed)
    x, wt = _arr(rng, n, h, w, c), _arr(rng, 3, 3, c)
    np.testing.assert_allclose(
        K.depthwise3x3(x, wt), ref.depthwise3x3_ref(x, wt),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [
    (1, 3, 3, 1), (16, 32, 32, 64), (2, 8, 8, 128), (4, 5, 9, 130),
])
def test_depthwise_forward_shapes(shape):
    rng = np.random.default_rng(sum(shape))
    x = _arr(rng, *shape)
    wt = _arr(rng, 3, 3, shape[-1])
    np.testing.assert_allclose(
        K.depthwise3x3(x, wt), ref.depthwise3x3_ref(x, wt),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c", [1, 32, 130])
def test_depthwise_grad_matches_ref(c):
    rng = np.random.default_rng(c)
    x, wt = _arr(rng, 2, 7, 6, c), _arr(rng, 3, 3, c)

    def f(fn, a, b):
        return jnp.sum(jnp.sin(fn(a, b)))

    gp = jax.grad(lambda a, b: f(K.depthwise3x3, a, b), (0, 1))(x, wt)
    gr = jax.grad(lambda a, b: f(ref.depthwise3x3_ref, a, b), (0, 1))(x, wt)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_depthwise_delta_kernel_is_identity():
    """A weight of 1 at the center tap and 0 elsewhere must copy the input."""
    rng = np.random.default_rng(0)
    x = _arr(rng, 2, 6, 6, 10)
    wt = jnp.zeros((3, 3, 10), jnp.float32).at[1, 1, :].set(1.0)
    np.testing.assert_allclose(K.depthwise3x3(x, wt), x, rtol=1e-6, atol=1e-6)


def test_depthwise_channels_independent():
    """Perturbing channel j must not change any other channel's output."""
    rng = np.random.default_rng(1)
    x = _arr(rng, 1, 8, 8, 6)
    wt = _arr(rng, 3, 3, 6)
    base = np.asarray(K.depthwise3x3(x, wt))
    x2 = x.at[..., 3].add(1.0)
    out2 = np.asarray(K.depthwise3x3(x2, wt))
    mask = np.ones(6, bool)
    mask[3] = False
    np.testing.assert_allclose(out2[..., mask], base[..., mask],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(out2[..., 3], base[..., 3])


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    h=st.integers(4, 14),
    w=st.integers(4, 14),
    cin=st.integers(1, 12),
    cout=st.integers(1, 20),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_forward_hypothesis(n, h, w, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    x, wt = _arr(rng, n, h, w, cin), _arr(rng, 3, 3, cin, cout)
    np.testing.assert_allclose(
        K.conv2d(x, wt, stride), ref.conv2d_ref(x, wt, stride),
        rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("k,stride", [(1, 1), (1, 2), (3, 1), (3, 2)])
def test_conv2d_kernel_sizes(k, stride):
    rng = np.random.default_rng(k * 10 + stride)
    x = _arr(rng, 2, 16, 16, 8)
    wt = _arr(rng, k, k, 8, 24)
    np.testing.assert_allclose(
        K.conv2d(x, wt, stride), ref.conv2d_ref(x, wt, stride),
        rtol=1e-3, atol=1e-3)


def test_conv2d_grad_matches_ref():
    rng = np.random.default_rng(9)
    x = _arr(rng, 2, 8, 8, 4)
    wt = _arr(rng, 3, 3, 4, 6)

    def f(fn, a, b):
        return jnp.sum(jnp.tanh(fn(a, b, 2)))

    gp = jax.grad(lambda a, b: f(K.conv2d, a, b), (0, 1))(x, wt)
    gr = jax.grad(lambda a, b: f(ref.conv2d_ref, a, b), (0, 1))(x, wt)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_conv2d_1x1_equals_matmul():
    """A 1×1 conv is exactly a per-pixel matmul."""
    rng = np.random.default_rng(5)
    x = _arr(rng, 2, 6, 6, 7)
    wt = _arr(rng, 1, 1, 7, 11)
    out = K.conv2d(x, wt, 1)
    expect = np.asarray(x).reshape(-1, 7) @ np.asarray(wt).reshape(7, 11)
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, 11), expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# sgd_update
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    numel=st.integers(1, 200_000),
    lr=st.floats(1e-4, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_hypothesis(numel, lr, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.standard_normal(numel).astype("float32"))
    g = jnp.asarray(rng.standard_normal(numel).astype("float32"))
    np.testing.assert_allclose(
        K.sgd_update(p, g, lr), ref.sgd_ref(p, g, lr), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", [(1,), (3, 3, 130), (3, 3, 4, 6), (65536,),
                                   (65537,), (79187,)])
def test_sgd_shapes(shape):
    rng = np.random.default_rng(sum(shape))
    p = jnp.asarray(rng.standard_normal(shape).astype("float32"))
    g = jnp.asarray(rng.standard_normal(shape).astype("float32"))
    out = K.sgd_update(p, g, 0.05)
    assert out.shape == p.shape
    np.testing.assert_allclose(out, ref.sgd_ref(p, g, 0.05),
                               rtol=1e-5, atol=1e-6)


def test_sgd_zero_lr_is_identity():
    rng = np.random.default_rng(2)
    p = jnp.asarray(rng.standard_normal(1000).astype("float32"))
    g = jnp.asarray(rng.standard_normal(1000).astype("float32"))
    np.testing.assert_array_equal(K.sgd_update(p, g, 0.0), p)


def test_sgd_descends_quadratic():
    """Iterating p -= lr·∇(½p²) must converge to 0."""
    p = jnp.full((64,), 10.0, jnp.float32)
    for _ in range(100):
        p = K.sgd_update(p, p, 0.1)
    assert float(jnp.max(jnp.abs(p))) < 1e-3


# ---------------------------------------------------------------------------
# analytical cost helpers (consumed by the SoC simulator)
# ---------------------------------------------------------------------------


def test_matmul_cost_positive_and_scales():
    c1 = K.matmul_cost(128, 128, 128)
    c2 = K.matmul_cost(256, 128, 128)
    assert c2["flops"] == 2 * c1["flops"]
    assert c1["flops"] > 0 and c1["bytes"] > 0


def test_depthwise_cost_memory_bound():
    """Depthwise AI must be far below matmul AI — the paper's §3.1 premise."""
    dw = K.depthwise_cost(16, 32, 32, 64)
    mm = K.matmul_cost(512, 512, 512)
    ai_dw = dw["flops"] / dw["bytes"]
    ai_mm = mm["flops"] / mm["bytes"]
    assert ai_dw < 10
    assert ai_mm > 20 * ai_dw
