# AOT round-trip tests: the HLO text we ship must (a) parse back into an
# XlaComputation, (b) execute on the CPU PJRT client with the metadata's
# input layout, and (c) reproduce the eager train step bit-for-bit-ish.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART, "meta", "index.json"))


pytestmark = pytest.mark.skipif(
    not _have_artifacts(), reason="run `make artifacts` first")


def _load_meta(name):
    with open(os.path.join(ART, "meta", f"{name}.json")) as f:
        return json.load(f)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_metadata_matches_specs(name):
    meta = _load_meta(name)
    specs = M.MODELS[name]["specs"]()
    assert [s["name"] for s in meta["params"]] == [s["name"] for s in specs]
    assert [s["shape"] for s in meta["params"]] == [s["shape"] for s in specs]
    assert meta["train_outputs"] == len(specs) + 1
    assert meta["batch"] == M.BATCH


@pytest.mark.parametrize("name", list(M.MODELS))
def test_hlo_text_parses(name):
    meta = _load_meta(name)
    for key in ("train", "eval"):
        path = os.path.join(ART, meta["artifacts"][key])
        text = open(path).read()
        assert "ENTRY" in text
        # parse back through the same XLA the rust crate links
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_jit_train_step_matches_eager():
    """The jitted (== what gets AOT-lowered) train step must match the
    eager step numerically. The authoritative HLO-text → PJRT → execute
    round-trip is exercised by the Rust integration tests
    (rust/tests/runtime_roundtrip.rs), which load these same artifacts."""
    name = "shufflenet_s"
    meta = _load_meta(name)
    cfg = M.MODELS[name]
    names = [s["name"] for s in meta["params"]]
    params = M.init_params(name, seed=7)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(meta["input_shape"]).astype("float32"))
    y = jnp.asarray(rng.integers(0, meta["num_classes"],
                                 size=meta["label_shape"]).astype("int32"))

    step = M.make_train_step(cfg["apply"], names, meta["learning_rate"])
    eager = step(*params, x, y)
    jitted = jax.jit(step)(*params, x, y)
    assert len(jitted) == meta["train_outputs"]
    for got, want in zip(jitted, eager):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_index_lists_all_models():
    with open(os.path.join(ART, "meta", "index.json")) as f:
        idx = json.load(f)
    assert set(idx["models"]) == set(M.MODELS)


def test_workload_jsons_exist():
    for f in ("workload_resnet34.json", "workload_mobilenet_v2.json",
              "workload_shufflenet_v2.json", "workload_matmul512.json",
              "workload_resnet_s.json", "workload_mobilenet_s.json",
              "workload_shufflenet_s.json"):
        path = os.path.join(ART, "meta", f)
        assert os.path.exists(path), f
        with open(path) as fh:
            d = json.load(fh)
        assert d["total_flops"] > 0


def test_matmul512_artifact_parses():
    text = open(os.path.join(ART, "matmul512.hlo.txt")).read()
    assert "ENTRY" in text
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
