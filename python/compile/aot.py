"""AOT bridge: lower L2 train/eval steps to HLO text for the Rust runtime.

Run once by ``make artifacts`` (never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Emits, per model in `model.MODELS`:
  artifacts/<model>_train.hlo.txt   (p0..pN, x, y) -> (p0'..pN', loss)
  artifacts/<model>_eval.hlo.txt    (p0..pN, x, y) -> (loss, n_correct)
  artifacts/meta/<model>.json       param order/shapes/init, io specs
plus the Fig-1b microbenchmark ``matmul512.hlo.txt`` and the workload
descriptors (`workloads.write_all`).

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Lowering goes stablehlo → XlaComputation with ``return_tuple=True``; the
Rust side unwraps the tuple with ``Literal::to_tuple``.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import workloads


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs_to_shapes(specs):
    return [jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32)
            for s in specs]


def lower_model(name: str, out_dir: str) -> dict:
    cfg = M.MODELS[name]
    specs = cfg["specs"]()
    names = [s["name"] for s in specs]
    x_spec = jax.ShapeDtypeStruct((M.BATCH,) + cfg["input_shape"], jnp.float32)
    y_spec = jax.ShapeDtypeStruct((M.BATCH,), jnp.int32)
    p_specs = _param_specs_to_shapes(specs)

    train = M.make_train_step(cfg["apply"], names, M.LEARNING_RATE)
    eval_ = M.make_eval_step(cfg["apply"], names)

    train_hlo = to_hlo_text(jax.jit(train).lower(*p_specs, x_spec, y_spec))
    eval_hlo = to_hlo_text(jax.jit(eval_).lower(*p_specs, x_spec, y_spec))

    train_path = f"{name}_train.hlo.txt"
    eval_path = f"{name}_eval.hlo.txt"
    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(eval_hlo)

    meta = {
        "name": name,
        "task": cfg["task"],
        "paper_model": cfg["paper_model"],
        "batch": M.BATCH,
        "learning_rate": M.LEARNING_RATE,
        "num_classes": cfg["num_classes"],
        "input_shape": list((M.BATCH,) + cfg["input_shape"]),
        "label_shape": [M.BATCH],
        "params": specs,
        "param_scalars": int(sum(
            int(jnp.prod(jnp.array(s["shape"]))) for s in specs)),
        "artifacts": {"train": train_path, "eval": eval_path},
        "train_outputs": len(specs) + 1,   # params' + loss
        "eval_outputs": 2,                 # loss, n_correct
        "workload": f"workload_{cfg['paper_model']}.json",
        "workload_small": f"workload_{name}.json",
    }
    with open(os.path.join(out_dir, "meta", f"{name}.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def lower_matmul512(out_dir: str) -> None:
    from .kernels import matmul_fwd_only
    spec = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def mm(x, y):
        return (matmul_fwd_only(x, y),)

    hlo = to_hlo_text(jax.jit(mm).lower(spec, spec))
    with open(os.path.join(out_dir, "matmul512.hlo.txt"), "w") as f:
        f.write(hlo)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(M.MODELS))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    os.makedirs(os.path.join(args.out, "meta"), exist_ok=True)

    for name in args.models:
        meta = lower_model(name, args.out)
        print(f"lowered {name}: {meta['param_scalars']} params "
              f"-> {meta['artifacts']}")
    lower_matmul512(args.out)
    workloads.write_all(os.path.join(args.out, "meta"))
    index = {
        "models": args.models,
        "microbench": ["matmul512.hlo.txt"],
    }
    with open(os.path.join(args.out, "meta", "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print("aot done")


if __name__ == "__main__":
    main()
