"""Analytical workload descriptors for the paper-scale models.

The SoC simulator (rust/src/soc/) times a *training step* from an op-level
descriptor: per op it needs FLOPs, bytes moved, and the op kind (compute-
bound convs/matmuls vs memory-bound depthwise/norm/elementwise). These
numbers are produced here, once, at artifact-build time — for the actual
paper-scale models (ResNet-34, MobileNetV2, ShuffleNetV2 at the paper's
batch size 16) — and written to ``artifacts/meta/workload_<name>.json``.

The descriptors also cover the small trainable variants (computed from the
same walker over `model.MODELS`' specs) so local examples can simulate the
exact model they are really training, and the 512×512 matmul of Fig 1b.

A backward pass is modeled as the standard 2× forward (one cotangent
matmul per forward matmul for dx plus one for dw), and the fused SGD
update as a 3-stream elementwise pass over the parameters. This is the
same accounting FedScale-style simulators use.
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

F32 = 4.0


class Walker:
    """Accumulates ops while walking a network; NHWC shapes."""

    def __init__(self, batch: int, h: int, w: int, c: int):
        self.n, self.h, self.w, self.c = batch, h, w, c
        self.ops: List[dict] = []
        self.param_scalars = 0

    # -- op emitters --------------------------------------------------------
    def _emit(self, name: str, kind: str, flops: float, bytes_: float,
              params: int = 0) -> None:
        self.ops.append({
            "name": name, "kind": kind,
            "flops": float(flops), "bytes": float(bytes_),
        })
        self.param_scalars += params

    def conv(self, name: str, cout: int, k: int = 3, stride: int = 1) -> None:
        n, h, w, cin = self.n, self.h, self.w, self.c
        ho, wo = -(-h // stride), -(-w // stride)
        flops = 2.0 * n * ho * wo * cout * k * k * cin
        bytes_ = F32 * (n * h * w * cin + k * k * cin * cout + n * ho * wo * cout)
        self._emit(name, "conv", flops, bytes_, k * k * cin * cout)
        self.h, self.w, self.c = ho, wo, cout

    def pw(self, name: str, cout: int) -> None:
        self.conv(name, cout, k=1, stride=1)
        self.ops[-1]["kind"] = "pw"

    def dw(self, name: str, stride: int = 1, k: int = 3) -> None:
        n, h, w, c = self.n, self.h, self.w, self.c
        ho, wo = -(-h // stride), -(-w // stride)
        flops = 2.0 * n * ho * wo * c * k * k
        bytes_ = F32 * (n * h * w * c + k * k * c + n * ho * wo * c)
        self._emit(name, "dw", flops, bytes_, k * k * c)
        self.h, self.w = ho, wo

    def norm(self, name: str) -> None:
        n, h, w, c = self.n, self.h, self.w, self.c
        elems = n * h * w * c
        self._emit(name, "norm", 8.0 * elems, 2 * F32 * elems, 2 * c)

    def act(self, name: str) -> None:
        elems = self.n * self.h * self.w * self.c
        self._emit(name, "act", 1.0 * elems, 2 * F32 * elems)

    def pool(self, name: str, stride: int = 2) -> None:
        n, h, w, c = self.n, self.h, self.w, self.c
        self._emit(name, "pool", n * h * w * c,
                   F32 * (n * h * w * c) * 1.25)
        self.h, self.w = -(-h // stride), -(-w // stride)

    def gap(self, name: str) -> None:
        n, h, w, c = self.n, self.h, self.w, self.c
        self._emit(name, "pool", n * h * w * c, F32 * n * h * w * c)
        self.h, self.w = 1, 1

    def linear(self, name: str, cout: int) -> None:
        n, cin = self.n, self.c
        flops = 2.0 * n * cin * cout
        bytes_ = F32 * (n * cin + cin * cout + n * cout)
        self._emit(name, "linear", flops, bytes_, cin * cout + cout)
        self.c = cout

    def add(self, name: str) -> None:
        elems = self.n * self.h * self.w * self.c
        self._emit(name, "add", elems, 3 * F32 * elems)


def _finish(walker: Walker, name: str, paper_batch: int) -> dict:
    """fwd ops -> full train-step descriptor (fwd + bwd + update)."""
    fwd = walker.ops
    bwd = [{
        "name": f"{o['name']}#bwd", "kind": o["kind"],
        "flops": 2.0 * o["flops"], "bytes": 2.0 * o["bytes"],
    } for o in reversed(fwd)]
    p = walker.param_scalars
    upd = [{"name": "sgd_update", "kind": "update",
            "flops": 2.0 * p, "bytes": 3.0 * F32 * p}]
    ops = fwd + bwd + upd
    tf = sum(o["flops"] for o in ops)
    tb = sum(o["bytes"] for o in ops)
    mem_bytes = sum(o["bytes"] for o in ops
                    if o["kind"] in ("dw", "norm", "act", "pool", "add",
                                     "update"))
    return {
        "name": name,
        "batch": paper_batch,
        "ops": ops,
        "param_scalars": p,
        "total_flops": tf,
        "total_bytes": tb,
        "arithmetic_intensity": tf / tb,
        "memory_bound_byte_fraction": mem_bytes / tb,
    }


# ---------------------------------------------------------------------------
# Paper-scale models (batch 16 per §5.1)
# ---------------------------------------------------------------------------


def resnet34(batch: int = 16) -> dict:
    """ResNet-34 on 32×32×1 speech spectrograms (FedScale-style stem)."""
    wk = Walker(batch, 32, 32, 1)
    wk.conv("stem", 64)
    wk.norm("stem_gn")
    wk.act("stem_relu")
    stages: List[Tuple[int, int, int]] = [
        (64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    for si, (c, blocks, first_stride) in enumerate(stages):
        for bi in range(blocks):
            s = first_stride if bi == 0 else 1
            pre_c = wk.c
            wk.conv(f"s{si}b{bi}.c1", c, stride=s)
            wk.norm(f"s{si}b{bi}.n1")
            wk.act(f"s{si}b{bi}.r1")
            wk.conv(f"s{si}b{bi}.c2", c)
            wk.norm(f"s{si}b{bi}.n2")
            if bi == 0 and (pre_c != c or s != 1):
                wk.ops.append({
                    "name": f"s{si}b{bi}.proj", "kind": "pw",
                    "flops": 2.0 * wk.n * wk.h * wk.w * pre_c * c,
                    "bytes": F32 * (wk.n * wk.h * wk.w * (pre_c + c)
                                    + pre_c * c),
                })
                wk.param_scalars += pre_c * c
            wk.add(f"s{si}b{bi}.skip")
            wk.act(f"s{si}b{bi}.r2")
    wk.gap("gap")
    wk.linear("head", 35)
    return _finish(wk, "resnet34", batch)


def mobilenet_v2(batch: int = 16) -> dict:
    """MobileNetV2 on 64×64×3, 600-way head (OpenImage tier)."""
    wk = Walker(batch, 64, 64, 3)
    wk.conv("stem", 32, stride=2)
    wk.norm("stem_gn")
    wk.act("stem_relu")
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    i = 0
    for t, c, n_rep, s in cfg:
        for r in range(n_rep):
            stride = s if r == 0 else 1
            cin = wk.c
            mid = cin * t
            p = f"ir{i}"
            if t != 1:
                wk.pw(f"{p}.expand", mid)
                wk.norm(f"{p}.expand_gn")
                wk.act(f"{p}.expand_relu")
            wk.dw(f"{p}.dw", stride=stride)
            wk.norm(f"{p}.dw_gn")
            wk.act(f"{p}.dw_relu")
            wk.pw(f"{p}.project", c)
            wk.norm(f"{p}.project_gn")
            if stride == 1 and cin == c:
                wk.add(f"{p}.skip")
            i += 1
    wk.pw("conv_last", 1280)
    wk.norm("last_gn")
    wk.act("last_relu")
    wk.gap("gap")
    wk.linear("head", 600)
    return _finish(wk, "mobilenet_v2", batch)


def shufflenet_v2(batch: int = 16) -> dict:
    """ShuffleNetV2 1.0× on 64×64×3, 600-way head."""
    wk = Walker(batch, 64, 64, 3)
    wk.conv("stem", 24, stride=2)
    wk.norm("stem_gn")
    wk.act("stem_relu")
    wk.pool("maxpool")
    stages = [(116, 4), (232, 8), (464, 4)]
    u = 0
    for c, reps in stages:
        for r in range(reps):
            p = f"su{u}"
            down = r == 0
            if down:
                # left branch: dw(s2) + pw
                wk_branch_c = wk.c
                wk.dw(f"{p}.ldw", stride=2)
                wk.norm(f"{p}.ldw_gn")
                wk.pw(f"{p}.lpw", c // 2)
                wk.norm(f"{p}.lpw_gn")
                wk.act(f"{p}.lrelu")
                # right branch operates on original res; approximate by
                # emitting its ops at the pre-branch resolution
                wk.h *= 2
                wk.w *= 2
                wk.c = wk_branch_c
                half = c // 2
            else:
                half = wk.c // 2
                wk.c = half
            wk.pw(f"{p}.pw1", half)
            wk.norm(f"{p}.pw1_gn")
            wk.act(f"{p}.r1")
            wk.dw(f"{p}.dw", stride=2 if down else 1)
            wk.norm(f"{p}.dw_gn")
            wk.pw(f"{p}.pw2", half)
            wk.norm(f"{p}.pw2_gn")
            wk.act(f"{p}.r2")
            wk.c = c  # concat + shuffle
            wk.add(f"{p}.shuffle")
            u += 1
    wk.pw("conv5", 1024)
    wk.norm("conv5_gn")
    wk.act("conv5_relu")
    wk.gap("gap")
    wk.linear("head", 600)
    return _finish(wk, "shufflenet_v2", batch)


def matmul512() -> dict:
    """Fig 1b microbenchmark: one 512×512×512 f32 matmul."""
    fl = 2.0 * 512 ** 3
    by = F32 * 3 * 512 * 512
    return {
        "name": "matmul512", "batch": 1,
        "ops": [{"name": "mm", "kind": "conv", "flops": fl, "bytes": by}],
        "param_scalars": 0,
        "total_flops": fl, "total_bytes": by,
        "arithmetic_intensity": fl / by,
        "memory_bound_byte_fraction": 0.0,
    }


def small_variant(model_name: str) -> dict:
    """Descriptor for one of the trainable small models, derived by
    replaying its apply() structure through the walker."""
    from . import model as M

    cfg = M.MODELS[model_name]
    h, w, c = cfg["input_shape"]
    wk = Walker(M.BATCH, h, w, c)
    if model_name == "resnet_s":
        wk.conv("stem", 16)
        wk.norm("stem_gn")
        wk.act("stem_relu")
        for i, (cin, cout) in enumerate(M.RESNET_STAGES):
            s = 2 if i > 0 else 1
            wk.conv(f"s{i}.c1", cout, stride=s)
            wk.norm(f"s{i}.n1")
            wk.act(f"s{i}.r1")
            wk.conv(f"s{i}.c2", cout)
            wk.norm(f"s{i}.n2")
            wk.add(f"s{i}.skip")
            wk.act(f"s{i}.r2")
        wk.gap("gap")
        wk.linear("head", cfg["num_classes"])
    elif model_name == "mobilenet_s":
        wk.conv("stem", 16)
        wk.norm("stem_gn")
        wk.act("stem_relu")
        for i, (cin, cout, exp, down) in enumerate(M.MOBILENET_BLOCKS):
            wk.pw(f"ir{i}.expand", cin * exp)
            wk.norm(f"ir{i}.e_gn")
            wk.act(f"ir{i}.e_r")
            wk.dw(f"ir{i}.dw")
            if down:
                wk.pool(f"ir{i}.pool")
            wk.norm(f"ir{i}.dw_gn")
            wk.act(f"ir{i}.dw_r")
            wk.pw(f"ir{i}.project", cout)
            wk.norm(f"ir{i}.p_gn")
        wk.gap("gap")
        wk.linear("head", cfg["num_classes"])
    elif model_name == "shufflenet_s":
        wk.conv("stem", 24)
        wk.norm("stem_gn")
        wk.act("stem_relu")
        for i, (c_in, down) in enumerate(M.SHUFFLENET_UNITS):
            half = c_in if down else c_in // 2
            if down:
                wk.dw(f"su{i}.ldw")
                wk.pool(f"su{i}.lpool")
                wk.norm(f"su{i}.ldw_gn")
                save = (wk.h, wk.w)
                wk.c = c_in
                wk.pw(f"su{i}.lpw", c_in)
                wk.norm(f"su{i}.lpw_gn")
                wk.h, wk.w = save
            wk.c = half
            wk.pw(f"su{i}.pw1", half)
            wk.norm(f"su{i}.pw1_gn")
            wk.act(f"su{i}.r1")
            wk.dw(f"su{i}.dw")
            if down:
                wk.pool(f"su{i}.pool")
            wk.norm(f"su{i}.dw_gn")
            wk.pw(f"su{i}.pw2", half)
            wk.norm(f"su{i}.pw2_gn")
            wk.act(f"su{i}.r2")
            wk.c = 2 * half if down else c_in
            wk.add(f"su{i}.shuffle")
        wk.gap("gap")
        wk.linear("head", cfg["num_classes"])
    else:
        raise ValueError(model_name)
    return _finish(wk, model_name, M.BATCH)


ALL_PAPER = {
    "resnet34": resnet34,
    "mobilenet_v2": mobilenet_v2,
    "shufflenet_v2": shufflenet_v2,
    "matmul512": matmul512,
}


def write_all(out_dir: str) -> None:
    import os
    os.makedirs(out_dir, exist_ok=True)
    for name, fn in ALL_PAPER.items():
        with open(os.path.join(out_dir, f"workload_{name}.json"), "w") as f:
            json.dump(fn(), f, indent=1)
    for small in ("resnet_s", "mobilenet_s", "shufflenet_s"):
        with open(os.path.join(out_dir, f"workload_{small}.json"), "w") as f:
            json.dump(small_variant(small), f, indent=1)
