"""L2 — JAX model definitions for Swan's three training workloads.

Three *trainable small variants* of the paper's models, preserving the op
mix that drives Swan's scheduling decisions (DESIGN.md substitution ledger):

- ``resnet_s``     residual CNN          — speech tier  (32×32×1 → 35 cls)
- ``mobilenet_s``  inverted residual+dw  — vision tier  (32×32×3 → 64 cls)
- ``shufflenet_s`` split/shuffle+dw      — vision tier  (32×32×3 → 64 cls)

Every convolution/linear funnels through the L1 Pallas kernels
(`kernels.conv2d` → im2col + MXU matmul; `kernels.depthwise3x3`), the
optimizer is the fused Pallas `sgd_update`, and fwd+bwd+update are traced
as ONE function (`train_step`) so AOT lowering emits a single HLO module
per model — the Rust runtime never orchestrates sub-steps.

Parameters are carried as a flat ``(name, array)`` list in sorted-name
order; the same ordering is recorded in the artifact metadata so the Rust
side can construct, feed and receive parameter buffers positionally.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d, depthwise3x3, matmul, sgd_update

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# Parameter specs: (name, shape, init) with init ∈ {he:<fan_in>, zeros, ones}
# ---------------------------------------------------------------------------


class SpecBuilder:
    """Collects parameter specs while a model's apply() is being defined."""

    def __init__(self) -> None:
        self.specs: List[dict] = []

    def conv(self, name: str, k: int, cin: int, cout: int) -> None:
        self.specs.append({
            "name": f"{name}.w", "shape": [k, k, cin, cout],
            "init": "he", "fan_in": k * k * cin,
        })

    def dw(self, name: str, c: int) -> None:
        self.specs.append({
            "name": f"{name}.w", "shape": [3, 3, c],
            "init": "he", "fan_in": 9,
        })

    def gn(self, name: str, c: int) -> None:
        self.specs.append({"name": f"{name}.gamma", "shape": [c], "init": "ones"})
        self.specs.append({"name": f"{name}.beta", "shape": [c], "init": "zeros"})

    def linear(self, name: str, cin: int, cout: int) -> None:
        self.specs.append({
            "name": f"{name}.w", "shape": [cin, cout],
            "init": "he", "fan_in": cin,
        })
        self.specs.append({"name": f"{name}.b", "shape": [cout], "init": "zeros"})

    def sorted_specs(self) -> List[dict]:
        return sorted(self.specs, key=lambda s: s["name"])


# ---------------------------------------------------------------------------
# Layer ops (jnp glue around the Pallas kernels)
# ---------------------------------------------------------------------------


def group_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               groups: int = 8) -> jax.Array:
    """GroupNorm over channels (NHWC); stateless, so the train step stays
    a pure function of (params, batch) — no running-stat side inputs."""
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(n, h, w, c) * gamma + beta


def avg_pool2(x: jax.Array) -> jax.Array:
    """2×2 average pool, stride 2 (all spatial dims here are powers of 2)."""
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def global_avg_pool(x: jax.Array) -> jax.Array:
    return x.mean(axis=(1, 2))


def channel_shuffle(x: jax.Array, groups: int = 2) -> jax.Array:
    n, h, w, c = x.shape
    x = x.reshape(n, h, w, groups, c // groups)
    x = jnp.swapaxes(x, 3, 4)
    return x.reshape(n, h, w, c)


def linear(params: Params, name: str, x: jax.Array) -> jax.Array:
    return matmul(x, params[f"{name}.w"]) + params[f"{name}.b"]


def conv_gn_relu(params: Params, name: str, x: jax.Array,
                 stride: int = 1) -> jax.Array:
    x = conv2d(x, params[f"{name}.w"], stride)
    x = group_norm(x, params[f"{name}_gn.gamma"], params[f"{name}_gn.beta"])
    return jax.nn.relu(x)


# ---------------------------------------------------------------------------
# resnet_s — residual CNN (paper tier: ResNet-34 on Google Speech)
# ---------------------------------------------------------------------------

RESNET_STAGES = [(16, 16), (16, 32), (32, 64)]  # (cin, cout), downsample ≥ stage 2


def resnet_s_specs() -> List[dict]:
    b = SpecBuilder()
    b.conv("stem", 3, 1, 16)
    b.gn("stem_gn", 16)
    for i, (cin, cout) in enumerate(RESNET_STAGES):
        p = f"s{i}"
        b.conv(f"{p}.c1", 3, cin, cout)
        b.gn(f"{p}.c1_gn", cout)
        b.conv(f"{p}.c2", 3, cout, cout)
        b.gn(f"{p}.c2_gn", cout)
        if cin != cout:
            b.conv(f"{p}.proj", 1, cin, cout)
    b.linear("head", 64, 35)
    return b.sorted_specs()


def resnet_s_apply(params: Params, x: jax.Array) -> jax.Array:
    x = conv_gn_relu(params, "stem", x)
    for i, (cin, cout) in enumerate(RESNET_STAGES):
        p = f"s{i}"
        down = i > 0
        h = conv2d(x, params[f"{p}.c1.w"], 2 if down else 1)
        h = group_norm(h, params[f"{p}.c1_gn.gamma"], params[f"{p}.c1_gn.beta"])
        h = jax.nn.relu(h)
        h = conv2d(h, params[f"{p}.c2.w"], 1)
        h = group_norm(h, params[f"{p}.c2_gn.gamma"], params[f"{p}.c2_gn.beta"])
        skip = x
        if cin != cout:
            skip = conv2d(skip, params[f"{p}.proj.w"], 2 if down else 1)
        x = jax.nn.relu(h + skip)
    return linear(params, "head", global_avg_pool(x))


# ---------------------------------------------------------------------------
# mobilenet_s — inverted residuals + depthwise (paper tier: MobileNetV2)
# ---------------------------------------------------------------------------

# (cin, cout, expand, downsample)
MOBILENET_BLOCKS = [
    (16, 24, 4, True),
    (24, 32, 4, True),
    (32, 64, 4, True),
    (64, 64, 4, False),
]


def mobilenet_s_specs() -> List[dict]:
    b = SpecBuilder()
    b.conv("stem", 3, 3, 16)
    b.gn("stem_gn", 16)
    for i, (cin, cout, exp, _down) in enumerate(MOBILENET_BLOCKS):
        p = f"ir{i}"
        mid = cin * exp
        b.conv(f"{p}.expand", 1, cin, mid)
        b.gn(f"{p}.expand_gn", mid)
        b.dw(f"{p}.dw", mid)
        b.gn(f"{p}.dw_gn", mid)
        b.conv(f"{p}.project", 1, mid, cout)
        b.gn(f"{p}.project_gn", cout)
    b.linear("head", 64, 64)
    return b.sorted_specs()


def mobilenet_s_apply(params: Params, x: jax.Array) -> jax.Array:
    x = conv_gn_relu(params, "stem", x)
    for i, (cin, cout, exp, down) in enumerate(MOBILENET_BLOCKS):
        p = f"ir{i}"
        h = conv2d(x, params[f"{p}.expand.w"], 1)
        h = group_norm(h, params[f"{p}.expand_gn.gamma"],
                       params[f"{p}.expand_gn.beta"])
        h = jax.nn.relu(h)
        # Stride-2 depthwise in MobileNetV2 is expressed as stride-1
        # depthwise + avg-pool so fwd AND bwd stay on the Pallas dw kernel
        # (see kernels/depthwise.py docstring).
        h = depthwise3x3(h, params[f"{p}.dw.w"])
        if down:
            h = avg_pool2(h)
        h = group_norm(h, params[f"{p}.dw_gn.gamma"], params[f"{p}.dw_gn.beta"])
        h = jax.nn.relu(h)
        h = conv2d(h, params[f"{p}.project.w"], 1)
        h = group_norm(h, params[f"{p}.project_gn.gamma"],
                       params[f"{p}.project_gn.beta"])
        if cin == cout and not down:
            h = h + x
        x = h
    return linear(params, "head", global_avg_pool(x))


# ---------------------------------------------------------------------------
# shufflenet_s — channel split/shuffle + depthwise (paper tier: ShuffleNetV2)
# ---------------------------------------------------------------------------

# (channels_in, downsample). Down units double channels (both halves kept).
SHUFFLENET_UNITS = [(24, True), (48, False), (48, True), (96, False)]


def shufflenet_s_specs() -> List[dict]:
    b = SpecBuilder()
    b.conv("stem", 3, 3, 24)
    b.gn("stem_gn", 24)
    for i, (c, down) in enumerate(SHUFFLENET_UNITS):
        p = f"su{i}"
        half = c if down else c // 2
        b.conv(f"{p}.pw1", 1, half, half)
        b.gn(f"{p}.pw1_gn", half)
        b.dw(f"{p}.dw", half)
        b.gn(f"{p}.dw_gn", half)
        b.conv(f"{p}.pw2", 1, half, half)
        b.gn(f"{p}.pw2_gn", half)
        if down:
            b.dw(f"{p}.ldw", c)
            b.gn(f"{p}.ldw_gn", c)
            b.conv(f"{p}.lpw", 1, c, c)
            b.gn(f"{p}.lpw_gn", c)
    b.linear("head", 96, 64)
    return b.sorted_specs()


def _shuffle_branch(params: Params, p: str, x: jax.Array,
                    down: bool) -> jax.Array:
    h = conv2d(x, params[f"{p}.pw1.w"], 1)
    h = group_norm(h, params[f"{p}.pw1_gn.gamma"], params[f"{p}.pw1_gn.beta"])
    h = jax.nn.relu(h)
    h = depthwise3x3(h, params[f"{p}.dw.w"])
    if down:
        h = avg_pool2(h)
    h = group_norm(h, params[f"{p}.dw_gn.gamma"], params[f"{p}.dw_gn.beta"])
    h = conv2d(h, params[f"{p}.pw2.w"], 1)
    h = group_norm(h, params[f"{p}.pw2_gn.gamma"], params[f"{p}.pw2_gn.beta"])
    return jax.nn.relu(h)


def shufflenet_s_apply(params: Params, x: jax.Array) -> jax.Array:
    x = conv_gn_relu(params, "stem", x)
    for i, (c, down) in enumerate(SHUFFLENET_UNITS):
        p = f"su{i}"
        if down:
            # both branches processed, channels double: left = dw+pw path
            left = depthwise3x3(x, params[f"{p}.ldw.w"])
            left = avg_pool2(left)
            left = group_norm(left, params[f"{p}.ldw_gn.gamma"],
                              params[f"{p}.ldw_gn.beta"])
            left = conv2d(left, params[f"{p}.lpw.w"], 1)
            left = group_norm(left, params[f"{p}.lpw_gn.gamma"],
                              params[f"{p}.lpw_gn.beta"])
            left = jax.nn.relu(left)
            right = _shuffle_branch(params, p, x, down=True)
        else:
            half = c // 2
            left, xr = x[..., :half], x[..., half:]
            right = _shuffle_branch(params, p, xr, down=False)
        x = channel_shuffle(jnp.concatenate([left, right], axis=-1))
    return linear(params, "head", global_avg_pool(x))


# ---------------------------------------------------------------------------
# Task heads: loss / train / eval (shared by all models)
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def _to_dict(names: Sequence[str], flat: Sequence[jax.Array]) -> Params:
    return dict(zip(names, flat))


def make_train_step(apply_fn: Callable[[Params, jax.Array], jax.Array],
                    names: Sequence[str], lr: float):
    """(p0..pN, x, y) -> (p0'..pN', loss): fwd, bwd and the fused Pallas
    SGD update traced as one function → one AOT HLO module."""

    def loss_fn(flat: Tuple[jax.Array, ...], x, y):
        return cross_entropy(apply_fn(_to_dict(names, flat), x), y)

    def train_step(*args):
        flat, x, y = args[:-2], args[-2], args[-1]
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        new = tuple(sgd_update(p, g, lr) for p, g in zip(flat, grads))
        return new + (loss,)

    return train_step


def make_eval_step(apply_fn: Callable[[Params, jax.Array], jax.Array],
                   names: Sequence[str]):
    """(p0..pN, x, y) -> (loss, n_correct)."""

    def eval_step(*args):
        flat, x, y = args[:-2], args[-2], args[-1]
        logits = apply_fn(_to_dict(names, flat), x)
        loss = cross_entropy(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y)
                          .astype(jnp.float32))
        return loss, correct

    return eval_step


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

MODELS = {
    "resnet_s": {
        "apply": resnet_s_apply,
        "specs": resnet_s_specs,
        "input_shape": (32, 32, 1),
        "num_classes": 35,
        "paper_model": "resnet34",
        "task": "speech",
    },
    "mobilenet_s": {
        "apply": mobilenet_s_apply,
        "specs": mobilenet_s_specs,
        "input_shape": (32, 32, 3),
        "num_classes": 64,
        "paper_model": "mobilenet_v2",
        "task": "vision",
    },
    "shufflenet_s": {
        "apply": shufflenet_s_apply,
        "specs": shufflenet_s_specs,
        "input_shape": (32, 32, 3),
        "num_classes": 64,
        "paper_model": "shufflenet_v2",
        "task": "vision",
    },
}

BATCH = 16       # paper §5.1: minibatch 16
LEARNING_RATE = 0.05  # paper §5.1


def init_params(name: str, seed: int = 0) -> List[jax.Array]:
    """Host-side init (tests only — Rust re-implements this from metadata)."""
    import numpy as np
    specs = MODELS[name]["specs"]()
    rng = np.random.RandomState(seed)
    out = []
    for s in specs:
        if s["init"] == "he":
            std = (2.0 / s["fan_in"]) ** 0.5
            out.append(jnp.asarray(
                rng.randn(*s["shape"]).astype("float32") * std))
        elif s["init"] == "ones":
            out.append(jnp.ones(s["shape"], jnp.float32))
        else:
            out.append(jnp.zeros(s["shape"], jnp.float32))
    return out
