"""L1 — Pallas kernels for Swan's compute hot-spots.

Four kernels, each with a pure-jnp oracle in `ref.py`:

- `matmul`      MXU-tiled matmul (custom_vjp; both cotangents are Pallas)
- `depthwise3x3` channel-tiled VPU depthwise conv (custom_vjp; dx and dw
                 are Pallas kernels)
- `conv2d`      im2col + `matmul` composition
- `sgd_update`  fused block-tiled optimizer step

All are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU behaviour is estimated from block shapes in
DESIGN.md §Perf.
"""
from .matmul import matmul, matmul_fwd_only, matmul_cost
from .depthwise import depthwise3x3, depthwise_cost
from .conv2d import conv2d, conv2d_cost
from .sgd import sgd_update, sgd_cost
from . import ref

__all__ = [
    "matmul", "matmul_fwd_only", "matmul_cost",
    "depthwise3x3", "depthwise_cost",
    "conv2d", "conv2d_cost",
    "sgd_update", "sgd_cost",
    "ref",
]
