"""Standard convolution as im2col + the MXU-tiled Pallas matmul.

The paper's compute-bound op (standard conv) is exactly the op that *does*
scale with cores on the phone — and on TPU it is the op that feeds the MXU.
We express it as explicit im2col (shift-and-concat, unambiguous (di, dj, c)
patch ordering) followed by `kernels.matmul.matmul`, whose forward and
backward are Pallas kernels. The im2col glue is plain jnp (pads, strided
slices, reshapes): XLA fuses it, and jax.grad differentiates it natively,
so the whole conv is differentiable end to end with the contraction —
the hot part — on the Pallas path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul, matmul_cost


def _im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """NHWC -> (N, Ho, Wo, kh*kw*C) patches, SAME padding.

    Patch features are ordered (di, dj, c) — matching
    w.reshape(kh*kw*Cin, Cout) for HWIO weights.
    """
    n, h, w, c = x.shape
    ho = -(-h // stride)  # ceil
    wo = -(-w // stride)
    pad_h = max((ho - 1) * stride + kh - h, 0)
    pad_w = max((wo - 1) * stride + kw - w, 0)
    top, left = pad_h // 2, pad_w // 2
    xp = jnp.pad(x, ((0, 0), (top, pad_h - top), (left, pad_w - left), (0, 0)))
    cols = []
    for di in range(kh):
        for dj in range(kw):
            cols.append(
                xp[:, di:di + ho * stride:stride, dj:dj + wo * stride:stride, :]
            )
    return jnp.concatenate(cols, axis=-1)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """SAME-padded conv: x (N,H,W,Cin) × w (kh,kw,Cin,Cout) -> NHWC."""
    n = x.shape[0]
    kh, kw, cin, cout = w.shape
    patches = _im2col(x, kh, kw, stride)
    _, ho, wo, kdim = patches.shape
    assert kdim == kh * kw * cin
    flat = patches.reshape(n * ho * wo, kdim)
    wmat = w.reshape(kdim, cout)
    out = matmul(flat, wmat)
    return out.reshape(n, ho, wo, cout)


def conv2d_cost(n: int, h: int, w: int, cin: int, cout: int,
                k: int = 3, stride: int = 1) -> dict:
    """Analytical forward cost of the conv via its im2col matmul."""
    ho = -(-h // stride)
    wo = -(-w // stride)
    return matmul_cost(n * ho * wo, cout, k * k * cin)
