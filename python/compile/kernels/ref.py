"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
compare each Pallas kernel — forward AND the custom_vjp backward — against
these implementations. They are intentionally written with stock
jax.numpy / lax primitives only, no Pallas, no cleverness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain f32 matmul: (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def depthwise3x3_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """3x3 depthwise convolution, NHWC, stride 1, SAME padding.

    x: (N, H, W, C), w: (3, 3, C) -> (N, H, W, C).
    """
    c = x.shape[-1]
    rhs = w.reshape(3, 3, 1, c)  # HWIO with feature_group_count=C
    return jax.lax.conv_general_dilated(
        x,
        rhs,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Standard conv, NHWC x HWIO -> NHWC, SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def sgd_ref(p: jax.Array, g: jax.Array, lr: float) -> jax.Array:
    """Fused SGD step: p <- p - lr * g."""
    return p - jnp.asarray(lr, p.dtype) * g


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x @ w + b."""
    return matmul_ref(x, w) + b
