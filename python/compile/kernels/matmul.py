"""MXU-tiled Pallas matmul with a Pallas backward pass (custom_vjp).

This is the workhorse kernel: standard convolutions (via im2col), linear
layers and the Fig-1b 512x512 microbenchmark all funnel through it.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates
(M/bm, N/bn, K/bk); each step pulls one (bm, bk) tile of `x` and one
(bk, bn) tile of `y` from HBM into VMEM and accumulates a (bm, bn)
output tile — i.e. the classic systolic-array feeding schedule the MXU
wants, expressed with BlockSpec index maps instead of CUDA threadblocks.
Accumulation happens in the revisited output block (the out index map
ignores k), which Pallas keeps resident in VMEM across the K loop.

Kernels are lowered with interpret=True: the CPU PJRT client cannot run
Mosaic custom-calls; real-TPU numbers are estimated analytically in
DESIGN.md §Perf from the block shapes chosen here.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tiling policy: fill VMEM first, grid only when the operands exceed it.
# The MXU wants ≥128-edge tiles; beyond that, a bigger resident block is
# strictly better (fewer HBM round-trips) until the three live tiles
# (x, y, o) blow the per-core VMEM budget. We budget 12 MiB of the 16 MiB
# for tiles, leaving room for double buffering of the streamed operand.
VMEM_TILE_BUDGET = 12 * 1024 * 1024
MAX_BLOCK_M = 4096
MAX_BLOCK_N = 512
MAX_BLOCK_K = 512


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _mm_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Grid point (i, j, k): o[i,j] (+)= x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )
    del nk  # grid bound only used by callers for cost metadata


def _matmul_padded(x: jax.Array, y: jax.Array,
                   bm: int, bn: int, bk: int) -> jax.Array:
    """Pallas matmul over already block-aligned operands."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _block_sizes(m: int, n: int, k: int) -> Tuple[int, int, int]:
    """VMEM-filling tile selection (see module docstring).

    Start from whole-operand blocks capped per axis, then halve the
    largest axis until x(bm,bk) + y(bk,bn) + o(bm,bn) fit the budget.
    """
    bm = min(MAX_BLOCK_M, _ceil_to(m, 8))
    bn = min(MAX_BLOCK_N, _ceil_to(n, 8))
    bk = min(MAX_BLOCK_K, _ceil_to(k, 8))

    def tile_bytes(a, b, c):
        return 4 * (a * c + c * b + a * b)

    while tile_bytes(bm, bn, bk) > VMEM_TILE_BUDGET and max(bm, bn, bk) > 8:
        if bm >= bn and bm >= bk:
            bm = max(8, bm // 2)
        elif bk >= bn:
            bk = max(8, bk // 2)
        else:
            bn = max(8, bn // 2)
    return bm, bn, bk


def matmul_fwd_only(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pallas matmul for arbitrary (M,K)@(K,N) f32 operands (no vjp)."""
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = _block_sizes(m, n, k)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _matmul_padded(xp, yp, bm, bn, bk)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul: forward and both cotangents are
    Pallas kernels (dx = g @ y^T, dy = x^T @ g)."""
    return matmul_fwd_only(x, y)


def _matmul_vjp_fwd(x, y):
    return matmul_fwd_only(x, y), (x, y)


def _matmul_vjp_bwd(res, g):
    x, y = res
    dx = matmul_fwd_only(g, y.T)
    dy = matmul_fwd_only(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def matmul_cost(m: int, n: int, k: int) -> dict:
    """Analytical cost of one forward matmul (for workload descriptors)."""
    return {
        "flops": 2.0 * m * n * k,
        "bytes": 4.0 * (m * k + k * n + m * n),
    }
