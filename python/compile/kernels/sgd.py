"""Fused SGD parameter update as a block-tiled Pallas kernel.

Applied leaf-wise over the parameter pytree inside the train step so the
whole optimizer lives in the same AOT-lowered HLO module as fwd/bwd —
the Rust runtime sees one executable per training step, never a separate
optimizer pass. The kernel is a pure elementwise stream (AI ≈ 1/12
flop/byte): on TPU it is bandwidth-bound, so the only tuning knob is the
block length, sized to keep the three streams (p, g, p') VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536  # 3 live f32 streams × 64 Ki × 4 B = 768 KiB per grid step


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _sgd_kernel(p_ref, g_ref, o_ref, *, lr: float):
    o_ref[...] = p_ref[...] - jnp.float32(lr) * g_ref[...]


def sgd_update(p: jax.Array, g: jax.Array, lr: float) -> jax.Array:
    """p <- p - lr·g for an arbitrary-shaped f32 leaf (not differentiated:
    it runs outside jax.grad, after the cotangents are computed)."""
    shape = p.shape
    flat_p = p.reshape(-1)
    flat_g = g.reshape(-1)
    n = flat_p.shape[0]
    blk = min(BLOCK, _ceil_to(n, 8))
    npad = _ceil_to(n, blk)
    fp = jnp.pad(flat_p, (0, npad - n))
    fg = jnp.pad(flat_g, (0, npad - n))
    out = pl.pallas_call(
        functools.partial(_sgd_kernel, lr=lr),
        grid=(npad // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(fp, fg)
    return out[:n].reshape(shape)


def sgd_cost(num_params: int) -> dict:
    """Analytical cost of one fused update over `num_params` scalars."""
    return {"flops": 2.0 * num_params, "bytes": 12.0 * num_params}
