"""Channel-tiled Pallas 3x3 depthwise convolution with Pallas backward.

Depthwise conv is the op the Swan paper's whole scheduling argument hangs
on (§3.1): it is memory-bound (arithmetic intensity ≈ 9 flops per loaded
element vs ~2·C for a standard conv), so on the paper's ARM SoCs adding
threads causes cache thrashing and *anti*-scaling. The TPU translation of
the same insight (DESIGN.md §Hardware-Adaptation): this op cannot feed the
MXU (no contraction over channels), so the kernel stays on the VPU and the
BlockSpec tiles over the *channel* axis — each grid step owns a channel
slab whose padded (N, H+2, W+2, bc) input block lives in VMEM while the
nine shifted multiply-accumulates stream over it exactly once.

Layout: NHWC, weights (3, 3, C), stride 1, SAME padding. Stride-2
downsampling in the models is expressed as stride-1 depthwise followed by
pooling so that forward and backward both stay on this one kernel (the
paper's models are re-expressed the same way; op mix is preserved — see
DESIGN.md substitution ledger).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Channel tile: up to 128 channels × (16, 34, 34) spatial block ≈ 2.4 MiB in VMEM
# for batch 16 — small enough to double-buffer within 16 MiB.
BLOCK_C = 128


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _dw_fwd_kernel(x_ref, w_ref, o_ref, *, h: int, w: int):
    """One channel slab: nine shifted MACs over the padded input block."""
    x = x_ref[...]  # (N, h+2, w+2, bc)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for di in range(3):
        for dj in range(3):
            acc += x[:, di:di + h, dj:dj + w, :] * w_ref[di, dj, :]
    o_ref[...] = acc


def _dw_dw_kernel(x_ref, g_ref, dw_ref, *, h: int, w: int):
    """Weight cotangent: dw[di,dj,c] = Σ_{n,y,x} x_shifted · g."""
    x = x_ref[...]  # (N, h+2, w+2, bc)
    g = g_ref[...]  # (N, h, w, bc)
    for di in range(3):
        for dj in range(3):
            prod = x[:, di:di + h, dj:dj + w, :] * g
            dw_ref[di, dj, :] = jnp.sum(prod, axis=(0, 1, 2))


def _pad_channels(a: jax.Array, cp: int) -> jax.Array:
    c = a.shape[-1]
    if c == cp:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, cp - c)]
    return jnp.pad(a, pad)


def _dw_call(x: jax.Array, w: jax.Array) -> jax.Array:
    """Forward Pallas call over channel-padded NHWC input."""
    n, h, wd, c = x.shape
    bc = min(BLOCK_C, c)
    cp = _ceil_to(c, bc)
    xp = _pad_channels(x, cp)
    wp = _pad_channels(w, cp)
    xpad = jnp.pad(xp, ((0, 0), (1, 1), (1, 1), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dw_fwd_kernel, h=h, w=wd),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((n, h + 2, wd + 2, bc), lambda ci: (0, 0, 0, ci)),
            pl.BlockSpec((3, 3, bc), lambda ci: (0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((n, h, wd, bc), lambda ci: (0, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, cp), jnp.float32),
        interpret=True,
    )(xpad, wp)
    return out[..., :c]


def _dw_weight_grad(x: jax.Array, g: jax.Array) -> jax.Array:
    """Pallas call computing the (3, 3, C) weight cotangent."""
    n, h, wd, c = x.shape
    bc = min(BLOCK_C, c)
    cp = _ceil_to(c, bc)
    xp = jnp.pad(_pad_channels(x, cp), ((0, 0), (1, 1), (1, 1), (0, 0)))
    gp = _pad_channels(g, cp)
    dw = pl.pallas_call(
        functools.partial(_dw_dw_kernel, h=h, w=wd),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((n, h + 2, wd + 2, bc), lambda ci: (0, 0, 0, ci)),
            pl.BlockSpec((n, h, wd, bc), lambda ci: (0, 0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((3, 3, bc), lambda ci: (0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((3, 3, cp), jnp.float32),
        interpret=True,
    )(xp, gp)
    return dw[..., :c]


@jax.custom_vjp
def depthwise3x3(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas depthwise conv (stride 1, SAME).

    Backward is two more Pallas calls: dx is a depthwise conv of the
    cotangent with the spatially flipped weights (correlation↔convolution
    duality), dw is the nine-tap reduction kernel above.
    """
    return _dw_call(x, w)


def _dw_vjp_fwd(x, w):
    return _dw_call(x, w), (x, w)


def _dw_vjp_bwd(res, g):
    x, w = res
    w_flip = w[::-1, ::-1, :]
    dx = _dw_call(g, w_flip)
    dw = _dw_weight_grad(x, g)
    return dx, dw


depthwise3x3.defvjp(_dw_vjp_fwd, _dw_vjp_bwd)


def depthwise_cost(n: int, h: int, w: int, c: int) -> dict:
    """Analytical forward cost: 9 MACs/element, streaming reads+writes."""
    elems = n * h * w * c
    return {
        "flops": 18.0 * elems,                     # 9 mul + 9 add
        "bytes": 4.0 * (n * (h + 2) * (w + 2) * c + elems + 9 * c),
    }
